//! Persistent worker pool with bounded channels.
//!
//! Each worker is an OS thread owning its column shard `S_k` of the score
//! matrix. The leader talks to workers over `sync_channel`s of
//! configurable depth — a full queue blocks the sender, which is the
//! backpressure mechanism (a leader can never run unboundedly ahead of a
//! slow worker). Fault injection (`Job::Stall`) lets tests exercise
//! straggler behaviour without real slow hardware.

use crate::linalg::{KernelConfig, Mat};
use std::sync::mpsc::{sync_channel, Receiver, Sender, SyncSender};
use std::sync::Arc;
use std::time::Duration;

/// Messages the leader sends to a worker.
pub enum Job {
    /// Install this worker's column shard (n × shard_width).
    SetShard(Mat),
    /// Compute the partial Gram `S_k S_kᵀ` (no damping — leader adds λ).
    Gram { reply: Sender<(usize, Mat)> },
    /// Compute the partial matvec `u_k = S_k v_k`.
    Matvec { v_k: Vec<f64>, reply: Sender<(usize, Vec<f64>)> },
    /// Compute the shard solution `x_k = (v_k − S_kᵀ z)/λ`.
    Apply { z: Arc<Vec<f64>>, v_k: Vec<f64>, lambda: f64, reply: Sender<(usize, Vec<f64>)> },
    /// Batched [`Job::Matvec`] (PR-5 bugfix): a k-RHS column panel
    /// `V_k` (k × shard_width, rows are right-hand-side slices) in one
    /// message — the partial `U_k = S_k·V_kᵀ` (n × k) comes back as one
    /// panel GEMM instead of k round-trips.
    MatvecMany { v_k: Mat, reply: Sender<(usize, Mat)> },
    /// Batched [`Job::Apply`]: the shard solution block
    /// `X_k = (V_k − (S_kᵀZ)ᵀ)/λ` (k × shard_width) for all k
    /// right-hand sides in one message.
    ApplyMany { z: Arc<Mat>, v_k: Mat, lambda: f64, reply: Sender<(usize, Mat)> },
    /// Fault injection: sleep before processing the next job (straggler).
    Stall(Duration),
    Shutdown,
}

/// Pool-level failures.
#[derive(Debug)]
pub enum PoolError {
    WorkerGone(usize),
    MissingShard(usize),
}

impl std::fmt::Display for PoolError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PoolError::WorkerGone(w) => write!(f, "worker {w} disconnected"),
            PoolError::MissingShard(w) => write!(f, "worker {w} has no shard installed"),
        }
    }
}

impl std::error::Error for PoolError {}

struct WorkerHandle {
    tx: SyncSender<Job>,
    join: Option<std::thread::JoinHandle<u64>>,
}

/// Leader-side pool handle.
pub struct WorkerPool {
    workers: Vec<WorkerHandle>,
}

impl WorkerPool {
    /// Spawn `workers` threads with `queue_depth`-bounded mailboxes,
    /// each running its kernels serially (deterministic default).
    pub fn spawn(workers: usize, queue_depth: usize) -> WorkerPool {
        WorkerPool::spawn_with_kernel(workers, queue_depth, KernelConfig::serial())
    }

    /// Spawn with an explicit kernel configuration: each worker's Gram
    /// product dispatches with `kernel.threads` threads on the shared
    /// persistent kernel pool (useful when workers ≪ cores).
    pub fn spawn_with_kernel(
        workers: usize,
        queue_depth: usize,
        kernel: KernelConfig,
    ) -> WorkerPool {
        assert!(workers > 0 && queue_depth > 0);
        let handles = (0..workers)
            .map(|id| {
                let (tx, rx) = sync_channel::<Job>(queue_depth);
                let join = std::thread::Builder::new()
                    .name(format!("dngd-worker-{id}"))
                    .spawn(move || worker_loop(id, rx, kernel))
                    .expect("spawn worker");
                WorkerHandle { tx, join: Some(join) }
            })
            .collect();
        WorkerPool { workers: handles }
    }

    pub fn len(&self) -> usize {
        self.workers.len()
    }

    pub fn is_empty(&self) -> bool {
        self.workers.is_empty()
    }

    /// Send a job to worker `w` (blocks when its queue is full —
    /// backpressure).
    pub fn send(&self, w: usize, job: Job) -> Result<(), PoolError> {
        self.workers[w].tx.send(job).map_err(|_| PoolError::WorkerGone(w))
    }

    /// Graceful shutdown; returns per-worker processed-job counts.
    pub fn shutdown(mut self) -> Vec<u64> {
        self.drain()
    }

    fn drain(&mut self) -> Vec<u64> {
        for h in &self.workers {
            let _ = h.tx.send(Job::Shutdown);
        }
        self.workers
            .iter_mut()
            .map(|h| h.join.take().map(|j| j.join().unwrap_or(0)).unwrap_or(0))
            .collect()
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.drain();
    }
}

fn worker_loop(id: usize, rx: Receiver<Job>, kernel: KernelConfig) -> u64 {
    let mut shard: Option<Mat> = None;
    let mut processed: u64 = 0;
    while let Ok(job) = rx.recv() {
        processed += 1;
        match job {
            Job::SetShard(m) => shard = Some(m),
            Job::Gram { reply } => {
                let Some(s) = shard.as_ref() else { continue };
                let w = crate::linalg::gemm::syrk_parallel(s, 0.0, kernel.threads);
                let _ = reply.send((id, w));
            }
            Job::Matvec { v_k, reply } => {
                let Some(s) = shard.as_ref() else { continue };
                let _ = reply.send((id, s.matvec(&v_k)));
            }
            Job::Apply { z, v_k, lambda, reply } => {
                let Some(s) = shard.as_ref() else { continue };
                let t = s.t_matvec(&z);
                let inv = 1.0 / lambda;
                let x_k: Vec<f64> =
                    v_k.iter().zip(&t).map(|(vj, tj)| inv * (vj - tj)).collect();
                let _ = reply.send((id, x_k));
            }
            Job::MatvecMany { v_k, reply } => {
                let Some(s) = shard.as_ref() else { continue };
                // U_k = S_k·V_kᵀ (n × k): one panel GEMM on the worker's
                // kernel configuration.
                let mut u = Mat::zeros(s.rows(), v_k.rows());
                crate::linalg::gemm::gemm_nt_threaded(1.0, s, &v_k, 0.0, &mut u, kernel.threads);
                let _ = reply.send((id, u));
            }
            Job::ApplyMany { z, v_k, lambda, reply } => {
                let Some(s) = shard.as_ref() else { continue };
                // T = S_kᵀ·Z (shard_width × k), then the Algorithm-1
                // line-4 combination per right-hand side.
                let (k, w) = v_k.shape();
                let mut t = Mat::zeros(w, k);
                crate::linalg::gemm::gemm_tn_threaded(1.0, s, &z, 0.0, &mut t, kernel.threads);
                let inv = 1.0 / lambda;
                let mut x_k = Mat::zeros(k, w);
                for r in 0..k {
                    let vrow = v_k.row(r);
                    let xrow = x_k.row_mut(r);
                    for j in 0..w {
                        xrow[j] = inv * (vrow[j] - t[(j, r)]);
                    }
                }
                let _ = reply.send((id, x_k));
            }
            Job::Stall(d) => std::thread::sleep(d),
            Job::Shutdown => break,
        }
    }
    processed
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;
    use std::sync::mpsc::channel;

    #[test]
    fn gram_and_matvec_roundtrip() {
        let mut rng = Rng::seed_from(420);
        let pool = WorkerPool::spawn(3, 2);
        let s = Mat::randn(6, 12, &mut rng);
        // Install thirds.
        for w in 0..3 {
            pool.send(w, Job::SetShard(s.slice_cols(w * 4, (w + 1) * 4))).unwrap();
        }
        // Partial Grams must sum to the full Gram.
        let (tx, rx) = channel();
        for w in 0..3 {
            pool.send(w, Job::Gram { reply: tx.clone() }).unwrap();
        }
        let mut total = Mat::zeros(6, 6);
        for _ in 0..3 {
            let (_, part) = rx.recv().unwrap();
            total.axpy(1.0, &part);
        }
        let full = crate::linalg::gemm::syrk(&s, 0.0);
        for (a, b) in total.as_slice().iter().zip(full.as_slice()) {
            assert!((a - b).abs() < 1e-10);
        }
        let counts = pool.shutdown();
        assert_eq!(counts.len(), 3);
        // Every worker processed SetShard + Gram + Shutdown.
        assert!(counts.iter().all(|&c| c == 3));
    }

    #[test]
    fn stall_injection_slows_but_does_not_break() {
        let mut rng = Rng::seed_from(421);
        let pool = WorkerPool::spawn(2, 1);
        let s = Mat::randn(4, 8, &mut rng);
        pool.send(0, Job::SetShard(s.slice_cols(0, 4))).unwrap();
        pool.send(1, Job::SetShard(s.slice_cols(4, 8))).unwrap();
        // Worker 1 is a straggler.
        pool.send(1, Job::Stall(Duration::from_millis(30))).unwrap();
        let (tx, rx) = channel();
        let t0 = std::time::Instant::now();
        pool.send(0, Job::Matvec { v_k: vec![1.0; 4], reply: tx.clone() }).unwrap();
        pool.send(1, Job::Matvec { v_k: vec![1.0; 4], reply: tx }).unwrap();
        let mut got = vec![];
        for _ in 0..2 {
            got.push(rx.recv().unwrap().0);
        }
        assert!(t0.elapsed() >= Duration::from_millis(25));
        got.sort();
        assert_eq!(got, vec![0, 1]);
    }

    #[test]
    fn missing_shard_job_is_skipped_not_crashed() {
        let pool = WorkerPool::spawn(1, 1);
        let (tx, rx) = channel();
        pool.send(0, Job::Gram { reply: tx }).unwrap();
        // No shard installed: worker skips; channel closes when we drop pool.
        drop(pool);
        assert!(rx.recv().is_err());
    }

    #[test]
    fn backpressure_blocks_sender() {
        // queue_depth 1 + a stalled worker: the 3rd send must block until
        // the worker drains — observe via a helper thread + timing.
        let pool = std::sync::Arc::new(WorkerPool::spawn(1, 1));
        pool.send(0, Job::Stall(Duration::from_millis(50))).unwrap(); // being processed
        pool.send(0, Job::Stall(Duration::from_millis(1))).unwrap(); // fills queue
        let p2 = pool.clone();
        let t0 = std::time::Instant::now();
        let h = std::thread::spawn(move || {
            p2.send(0, Job::Stall(Duration::from_millis(1))).unwrap(); // must wait
            t0.elapsed()
        });
        let waited = h.join().unwrap();
        assert!(waited >= Duration::from_millis(30), "sender did not backpressure: {waited:?}");
    }
}
