//! Tree reduction of partial results — the simulated all-reduce.
//!
//! Partial Gram matrices from W workers are summed pairwise in ⌈log₂W⌉
//! levels, each level's sums computed concurrently, mirroring the
//! communication schedule a real collective would run across devices.

use crate::linalg::Mat;

/// Sum a vector of equally-shaped matrices by pairwise tree reduction.
/// Level sums run on scoped threads (up to `threads` concurrent pairs).
pub fn tree_reduce_mats(mut parts: Vec<Mat>, threads: usize) -> Mat {
    assert!(!parts.is_empty());
    let shape = parts[0].shape();
    for p in &parts {
        assert_eq!(p.shape(), shape, "tree_reduce over mismatched shapes");
    }
    while parts.len() > 1 {
        let pairs = parts.len() / 2;
        let odd = parts.len() % 2 == 1;
        let mut next: Vec<Mat> = Vec::with_capacity(pairs + usize::from(odd));
        if threads > 1 && pairs > 1 {
            // Take ownership of pairs, sum concurrently.
            let mut drained = parts;
            let tail = if odd { drained.pop() } else { None };
            std::thread::scope(|scope| {
                let mut handles = Vec::with_capacity(pairs);
                let mut iter = drained.into_iter();
                while let (Some(mut a), Some(b)) = (iter.next(), iter.next()) {
                    handles.push(scope.spawn(move || {
                        a.axpy(1.0, &b);
                        a
                    }));
                }
                for h in handles {
                    next.push(h.join().expect("reduce worker panicked"));
                }
            });
            if let Some(t) = tail {
                next.push(t);
            }
        } else {
            let mut iter = parts.into_iter();
            while let Some(mut a) = iter.next() {
                if let Some(b) = iter.next() {
                    a.axpy(1.0, &b);
                }
                next.push(a);
            }
        }
        parts = next;
    }
    parts.pop().unwrap()
}

/// Sum vectors (leader-side reduction of partial `S_k v_k` matvecs).
pub fn reduce_vecs(parts: &[Vec<f64>]) -> Vec<f64> {
    assert!(!parts.is_empty());
    let len = parts[0].len();
    let mut out = vec![0.0; len];
    for p in parts {
        assert_eq!(p.len(), len);
        for (o, x) in out.iter_mut().zip(p) {
            *o += x;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;

    #[test]
    fn matches_serial_sum_any_count() {
        let mut rng = Rng::seed_from(410);
        for &count in &[1usize, 2, 3, 4, 5, 7, 8, 13] {
            let parts: Vec<Mat> = (0..count).map(|_| Mat::randn(9, 9, &mut rng)).collect();
            let mut expect = Mat::zeros(9, 9);
            for p in &parts {
                expect.axpy(1.0, p);
            }
            for &threads in &[1usize, 4] {
                let got = tree_reduce_mats(parts.clone(), threads);
                for (a, b) in got.as_slice().iter().zip(expect.as_slice()) {
                    assert!((a - b).abs() < 1e-12, "count={count} threads={threads}");
                }
            }
        }
    }

    #[test]
    fn vec_reduce() {
        let parts = vec![vec![1.0, 2.0], vec![10.0, 20.0], vec![100.0, 200.0]];
        assert_eq!(reduce_vecs(&parts), vec![111.0, 222.0]);
    }
}
