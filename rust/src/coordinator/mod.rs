//! L3 coordinator: the distributed training runtime.
//!
//! The paper (and RVB+23, whose parallelization strategy it shares — §3)
//! distributes Algorithm 1 by sharding the score matrix **along the
//! parameter axis m**: each of W workers owns an n×(m/W) column shard
//! `S_k`. One damped solve then decomposes as
//!
//! ```text
//! leader:  W = Σ_k S_k S_kᵀ + λĨ     ← partial Grams, tree-reduced
//!          L = Chol(W)
//!          u = Σ_k S_k v_k            ← partial matvecs, tree-reduced
//!          z = L⁻ᵀ L⁻¹ u              ← O(n²), leader-local
//! worker:  x_k = (v_k − S_kᵀ z)/λ    ← embarrassingly parallel
//! ```
//!
//! Only n×n matrices and n-vectors ever cross worker boundaries — O(n²)
//! communication for an O(nm) problem, which is what makes the scheme
//! scale. The modules:
//!
//! * [`shard`] — the m-axis [`ShardPlan`] (exact-cover invariants);
//! * [`reduce`] — pairwise tree reduction of partial results;
//! * [`pool`] — persistent worker threads with bounded (backpressure)
//!   channels, typed retryable/fatal faults, and fault injection for
//!   tests; since PR 7 a worker hosts many sessions at once (shards are
//!   keyed by session id) and is driven through the
//!   [`crate::serve::ShardTransport`] abstraction, so the same solver
//!   runs against in-process channels or out-of-process sockets;
//! * [`sharded`] — [`ShardedCholSolver`], the distributed Algorithm 1
//!   implementing [`crate::solver::DampedSolver`], plus the owning
//!   [`ShardedWindowSession`] used by the serving layer (distributed
//!   streaming `update_rows`);
//! * [`trainer`] — the end-to-end NGD trainer driving model, data,
//!   solver, metrics, full-state checkpoints, and the numerical-health
//!   sentinel (NaN/divergence/λ-runaway detection with bounded
//!   rollback);
//! * [`chaos`] — the train-target chaos harness pinning the
//!   kill-anywhere bit-identical-resume guarantee
//!   (`dngd chaos --target train`).

pub mod chaos;
pub mod pool;
pub mod reduce;
pub mod shard;
pub mod sharded;
pub mod trainer;

pub use chaos::{TrainChaosOptions, TrainChaosReport};
pub use pool::{PoolError, WorkerPool};
pub use reduce::tree_reduce_mats;
pub use shard::ShardPlan;
pub use sharded::{ShardedCholSolver, ShardedFactor, ShardedWindowSession};
pub use trainer::{TrainError, TrainReport, TrainStats, Trainer};
