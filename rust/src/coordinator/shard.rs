//! Parameter-axis shard planning.

/// A partition of the parameter axis `[0, m)` into contiguous worker
/// shards, balanced to within one column.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardPlan {
    pub m: usize,
    /// Half-open `[start, end)` per worker; non-empty, sorted, disjoint,
    /// exact cover of `[0, m)`.
    pub ranges: Vec<(usize, usize)>,
}

impl ShardPlan {
    /// Balanced plan: first `m % workers` shards get one extra column.
    /// Workers beyond `m` would get empty shards, so the effective worker
    /// count is `min(workers, m)`.
    pub fn balanced(m: usize, workers: usize) -> ShardPlan {
        assert!(m > 0 && workers > 0);
        let w = workers.min(m);
        let base = m / w;
        let rem = m % w;
        let mut ranges = Vec::with_capacity(w);
        let mut start = 0;
        for i in 0..w {
            let len = base + usize::from(i < rem);
            ranges.push((start, start + len));
            start += len;
        }
        ShardPlan { m, ranges }
    }

    pub fn workers(&self) -> usize {
        self.ranges.len()
    }

    /// Verify the exact-cover invariant (also property-tested).
    pub fn validate(&self) -> Result<(), String> {
        let mut cursor = 0;
        for &(s, e) in &self.ranges {
            if s != cursor {
                return Err(format!("gap or overlap at {s} (expected {cursor})"));
            }
            if e <= s {
                return Err(format!("empty shard [{s},{e})"));
            }
            cursor = e;
        }
        if cursor != self.m {
            return Err(format!("cover ends at {cursor}, expected {}", self.m));
        }
        Ok(())
    }

    /// Which shard owns column `j`.
    pub fn owner(&self, j: usize) -> usize {
        assert!(j < self.m);
        // Balanced plans are at most two sizes; binary search is exact.
        self.ranges.partition_point(|&(_, e)| e <= j)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;

    #[test]
    fn balanced_plans_validate() {
        for &(m, w) in &[(1usize, 1usize), (10, 3), (100, 7), (5, 8), (64, 64), (1000, 16)] {
            let plan = ShardPlan::balanced(m, w);
            plan.validate().unwrap();
            assert_eq!(plan.workers(), w.min(m));
            // Balance: sizes differ by at most 1.
            let sizes: Vec<usize> = plan.ranges.iter().map(|&(s, e)| e - s).collect();
            let (lo, hi) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
            assert!(hi - lo <= 1);
        }
    }

    #[test]
    fn owner_is_consistent() {
        let plan = ShardPlan::balanced(100, 7);
        for j in 0..100 {
            let o = plan.owner(j);
            let (s, e) = plan.ranges[o];
            assert!(s <= j && j < e);
        }
    }

    /// Property test (from-scratch randomized harness): random (m, w)
    /// pairs must always produce an exact cover.
    #[test]
    fn property_exact_cover_random() {
        let mut rng = Rng::seed_from(400);
        for _ in 0..500 {
            let m = 1 + rng.below(5000);
            let w = 1 + rng.below(40);
            let plan = ShardPlan::balanced(m, w);
            plan.validate().unwrap();
            let total: usize = plan.ranges.iter().map(|&(s, e)| e - s).sum();
            assert_eq!(total, m);
        }
    }
}
