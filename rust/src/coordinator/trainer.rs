//! End-to-end NGD trainer: corpus → tokenizer → transformer → per-sample
//! scores (parallel over the batch) → damped solve (PJRT artifact,
//! sharded-native, or serial-native) → parameter update → metrics →
//! full-state checkpoints.
//!
//! Durability (PR 9): checkpoints carry the *complete* training state —
//! parameters, optimizer state (momentum, damping scalar, streaming
//! window via a replayable session log), and the batch-RNG stream
//! position — so a run killed at any step boundary and resumed from its
//! latest checkpoint re-joins the unfailed trajectory **bit-identically**
//! (pinned by `tests/durability.rs` and `dngd chaos --target train`).
//! A numerical-health sentinel guards the step loop: NaN/Inf trips,
//! loss-divergence and λ-runaway detection with hysteresis, and
//! automatic rollback to the last good state with λ escalation, bounded
//! by `train.max_rollbacks` before a typed [`TrainError::Diverged`].

use crate::checkpoint::{
    checkpoint_path, recover_latest, CheckpointError, OptimizerState, SgdState, TrainState,
};
use crate::config::Config;
use crate::data::{BatchIter, CharTokenizer, Rng, SyntheticCorpus};
use crate::linalg::Mat;
use crate::metrics::MetricsLog;
use crate::model::{BatchEval, Transformer, TransformerConfig};
use crate::ngd::{DampingSchedule, NaturalGradient, Sgd};
use crate::runtime::{ArtifactRegistry, Backend};
use crate::solver::{DampedSolver, Precision, SolveError, SolverKind, SolverRegistry};
use std::path::Path;
use std::time::Instant;

/// Which optimizer drives the run (the e2e example compares them).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OptimizerChoice {
    Ngd,
    Sgd,
}

/// Typed trainer errors (PR 9) — checkpoint and health failures are no
/// longer squeezed through `SolveError::BadInput` strings.
#[derive(Debug)]
pub enum TrainError {
    /// The damped solve failed (λ backoff exhausted, bad input, …).
    Solve(SolveError),
    /// Checkpoint I/O / corruption / version skew.
    Checkpoint(CheckpointError),
    /// A checkpoint loaded cleanly but does not fit this run (wrong
    /// parameter count, optimizer kind, or window configuration).
    Mismatch(String),
    /// The health sentinel exhausted its rollback budget
    /// (`train.max_rollbacks`).
    Diverged { step: usize, rollbacks: usize, detail: String },
}

impl std::fmt::Display for TrainError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TrainError::Solve(e) => write!(f, "solver: {e}"),
            TrainError::Checkpoint(e) => write!(f, "checkpoint: {e}"),
            TrainError::Mismatch(m) => write!(f, "checkpoint mismatch: {m}"),
            TrainError::Diverged { step, rollbacks, detail } => write!(
                f,
                "training diverged at step {step} after {rollbacks} rollback(s): {detail}"
            ),
        }
    }
}

impl std::error::Error for TrainError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            TrainError::Solve(e) => Some(e),
            TrainError::Checkpoint(e) => Some(e),
            _ => None,
        }
    }
}

impl From<SolveError> for TrainError {
    fn from(e: SolveError) -> Self {
        TrainError::Solve(e)
    }
}

impl From<CheckpointError> for TrainError {
    fn from(e: CheckpointError) -> Self {
        TrainError::Checkpoint(e)
    }
}

/// Durability / health counters, observable after (or during) a run.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct TrainStats {
    /// Non-finite loss/gradient/score/parameter detections.
    pub nan_trips: usize,
    /// Loss-divergence sentinel trips (loss > ratio × best for
    /// `divergence_patience` consecutive steps).
    pub divergence_trips: usize,
    /// λ-runaway sentinel trips (λ pinned at the LM ceiling for
    /// `divergence_patience` consecutive steps).
    pub lambda_runaway_trips: usize,
    /// Rollbacks to the last good state actually performed.
    pub rollbacks: usize,
    /// λ escalations applied on rollback (NGD only).
    pub lambda_escalations: usize,
    /// Full-state checkpoints written.
    pub checkpoints_saved: usize,
    /// Corrupt checkpoints quarantined (renamed `*.corrupt`) during
    /// recovery scans.
    pub quarantined: usize,
    /// Healthy checkpoints from another format generation skipped
    /// during recovery scans.
    pub version_skipped: usize,
    /// Step the run resumed from, if it resumed.
    pub resumed_from: Option<usize>,
}

/// Final report of a training run.
#[derive(Debug, Clone)]
pub struct TrainReport {
    /// Completed steps (the step cursor after this run segment).
    pub steps: usize,
    pub params: usize,
    pub initial_loss: f64,
    pub final_loss: f64,
    /// Loss in bits/char (NLL / ln 2).
    pub final_bits_per_char: f64,
    pub wall_secs: f64,
    pub backend: String,
    /// Durability / health counters for the run.
    pub stats: TrainStats,
}

/// The end-to-end trainer.
pub struct Trainer {
    pub cfg: Config,
    pub model: Transformer,
    pub tokenizer: CharTokenizer,
    tokens: Vec<u32>,
    pub params: Vec<f64>,
    backend_name: String,
    solver: TrainSolver,
    eval_threads: usize,
    /// Step cursor: `run` continues from here (0 fresh, >0 after a
    /// resume or a previous partial run).
    start_step: usize,
    /// Armed batch-RNG position for the next `run` (the data cursor of
    /// the restored/continued stream).
    resume_rng: Option<([u64; 4], Option<f64>)>,
    stats: TrainStats,
}

enum TrainSolver {
    Ngd(NaturalGradient),
    Sgd(Sgd),
}

impl Trainer {
    /// Build a trainer from config: generates the corpus, fits the
    /// tokenizer, initializes the model, selects the solve backend.
    pub fn new(cfg: &Config, optimizer: OptimizerChoice) -> Result<Trainer, String> {
        let mut rng = Rng::seed_from(cfg.train.seed);
        let text = SyntheticCorpus::generate(cfg.train.corpus_len, &mut rng);
        let tokenizer = CharTokenizer::fit(&text);
        let tokens = tokenizer.encode(&text);

        let tcfg = TransformerConfig {
            vocab: tokenizer.vocab_size(),
            dim: cfg.model.dim,
            heads: cfg.model.heads,
            layers: cfg.model.layers,
            context: cfg.model.context,
            mlp_hidden: cfg.model.mlp_hidden,
        };
        tcfg.validate()?;
        let model = Transformer::new(tcfg);
        let params = model.init_params(&mut rng);
        let m = model.num_params();
        let n = cfg.train.batch_size;

        // Backend selection through the solver registry: PJRT artifact if
        // one matches (n, m) and artifacts are enabled; sharded-native
        // when workers > 1 and the kind is the shardable `chol`; otherwise
        // a registry-built serial solver of the configured kind with its
        // per-solver options (cg tolerance, budgets, threads, …).
        let registry = SolverRegistry::new(cfg.solver.options());
        // Mixed precision (PR 6) lives in the native chol/rvb sessions;
        // the sharded and PJRT backends are f64-only, so requesting it
        // pins the solve to the registry-built native solver rather than
        // silently dropping the mode.
        let mixed = cfg.solver.precision == Precision::Mixed;
        if mixed && cfg.solver.kind == SolverKind::Chol
            && (cfg.coordinator.workers > 1 || cfg.coordinator.use_artifacts)
        {
            eprintln!(
                "[trainer] solver.precision = mixed has no sharded/artifact backend; \
                 the solve runs on the native mixed-precision session"
            );
        }
        let shardable =
            cfg.solver.kind == SolverKind::Chol && cfg.coordinator.workers > 1 && !mixed;
        if cfg.solver.kind != SolverKind::Chol
            && (cfg.coordinator.workers > 1 || cfg.coordinator.use_artifacts)
        {
            // Not silently ignored (the config policy): only `chol` has a
            // sharded / PJRT-artifact backend today.
            eprintln!(
                "[trainer] solver.kind = {:?} has no sharded/artifact backend; \
                 coordinator.workers/use_artifacts apply to batch eval only — \
                 the solve runs serial native",
                cfg.solver.kind.as_str()
            );
        }
        let sharded = || -> (Box<dyn DampedSolver>, String) {
            (
                Box::new(super::ShardedCholSolver::new(
                    cfg.coordinator.workers,
                    cfg.coordinator.queue_depth,
                )),
                format!("sharded×{}", cfg.coordinator.workers),
            )
        };
        let (solver_box, backend_name): (Box<dyn DampedSolver>, String) =
            if cfg.coordinator.use_artifacts && cfg.solver.kind == SolverKind::Chol && !mixed {
                let reg = ArtifactRegistry::scan(Path::new(&cfg.coordinator.artifact_dir));
                match Backend::select(&reg, n, m, cfg.solver.threads) {
                    Backend::Pjrt(p) => (Box::new(p), "pjrt".to_string()),
                    Backend::Native(_) if shardable => sharded(),
                    Backend::Native(c) => (Box::new(c), "native".to_string()),
                }
            } else if shardable {
                sharded()
            } else {
                (registry.build(cfg.solver.kind), "native".to_string())
            };

        let solver = match optimizer {
            OptimizerChoice::Ngd => {
                let damping = if cfg.solver.adaptive {
                    // LM policy: grow λ when a step fails to improve the
                    // loss — stabilizes mini-batch NGD, where n ≪ m makes
                    // the per-batch Fisher noisy late in training.
                    DampingSchedule::LevenbergMarquardt {
                        lambda: cfg.solver.lambda,
                        grow: 2.0,
                        shrink: 0.9,
                        min: cfg.solver.lambda_min,
                        max: cfg.solver.lambda_max,
                    }
                } else if cfg.solver.lambda_decay < 1.0 {
                    DampingSchedule::ExponentialDecay {
                        initial: cfg.solver.lambda,
                        decay: cfg.solver.lambda_decay,
                        min: cfg.solver.lambda_min,
                    }
                } else {
                    DampingSchedule::Constant { lambda: cfg.solver.lambda }
                };
                let mut ngd = NaturalGradient::new(solver_box, damping, cfg.train.learning_rate)
                    .with_momentum(cfg.train.momentum);
                if cfg.train.trust_radius > 0.0 {
                    ngd = ngd.with_trust_radius(cfg.train.trust_radius);
                }
                if cfg.solver.window > 0 {
                    // Sliding-window streaming NGD (PR 5): the Fisher
                    // comes from the last `solver.window` score rows;
                    // each step rotates the batch through the
                    // chol/rvb owned-window session (O(knm + kn²),
                    // zero full-Gram SYRKs) or, for kinds without a
                    // rotatable factor, refactors the window cold.
                    ngd = ngd.with_window(cfg.solver.window, cfg.solver.refresh_every);
                }
                TrainSolver::Ngd(ngd)
            }
            OptimizerChoice::Sgd => TrainSolver::Sgd(
                Sgd::new(cfg.train.learning_rate).with_momentum(cfg.train.momentum),
            ),
        };

        Ok(Trainer {
            cfg: cfg.clone(),
            model,
            tokenizer,
            tokens,
            params,
            backend_name,
            solver,
            eval_threads: cfg.coordinator.workers.max(1),
            start_step: 0,
            resume_rng: None,
            stats: TrainStats::default(),
        })
    }

    /// Backend label ("pjrt", "sharded×W", "native").
    pub fn backend(&self) -> &str {
        &self.backend_name
    }

    /// Durability / health counters accumulated so far.
    pub fn stats(&self) -> &TrainStats {
        &self.stats
    }

    /// Batch evaluation parallelized over samples: per-sample backprop is
    /// embarrassingly parallel, so the batch is split across threads and
    /// the 1/√n-scaled rows are restitched with the global scaling.
    pub fn eval_batch_parallel(&self, contexts: &[Vec<u32>], targets: &[u32]) -> BatchEval {
        let n = contexts.len();
        let threads = self.eval_threads.min(n).max(1);
        if threads == 1 {
            return self.model.batch_eval(&self.params, contexts, targets);
        }
        let chunk = n.div_ceil(threads);
        let mut pieces: Vec<Option<BatchEval>> = Vec::new();
        for _ in 0..threads {
            pieces.push(None);
        }
        std::thread::scope(|scope| {
            let mut handles = Vec::new();
            for t in 0..threads {
                let lo = t * chunk;
                let hi = ((t + 1) * chunk).min(n);
                if lo >= hi {
                    break;
                }
                let model = &self.model;
                let params = &self.params;
                let ctx = &contexts[lo..hi];
                let tgt = &targets[lo..hi];
                handles.push((t, scope.spawn(move || model.batch_eval(params, ctx, tgt))));
            }
            for (t, h) in handles {
                pieces[t] = Some(h.join().expect("eval worker panicked"));
            }
        });
        // Merge: rows were scaled by 1/√n_sub inside each piece; rescale
        // to the global 1/√n. Loss/grad are weighted by n_sub/n.
        let m = self.model.num_params();
        let mut scores = Mat::zeros(n, m);
        let mut grad = vec![0.0; m];
        let mut loss = 0.0;
        let mut row = 0usize;
        for piece in pieces.into_iter().flatten() {
            let n_sub = piece.scores.rows();
            let rescale = (n_sub as f64).sqrt() / (n as f64).sqrt();
            for i in 0..n_sub {
                let src = piece.scores.row(i);
                let dst = scores.row_mut(row);
                for j in 0..m {
                    dst[j] = src[j] * rescale;
                }
                row += 1;
            }
            let w = n_sub as f64 / n as f64;
            loss += w * piece.loss;
            for j in 0..m {
                grad[j] += w * piece.grad[j];
            }
        }
        assert_eq!(row, n);
        BatchEval { loss, grad, scores }
    }

    /// Snapshot the full training state at a step boundary (`step` =
    /// completed steps; `rng` = the batch iterator's data cursor).
    fn capture_state(&self, step: usize, rng: &Rng) -> TrainState {
        let (rng_words, rng_cached) = rng.state();
        TrainState {
            step,
            params: self.params.clone(),
            rng_words,
            rng_cached,
            optimizer: match &self.solver {
                TrainSolver::Ngd(ngd) => OptimizerState::Ngd(ngd.export_state()),
                TrainSolver::Sgd(sgd) => {
                    OptimizerState::Sgd(SgdState { velocity: sgd.velocity().to_vec() })
                }
            },
        }
    }

    /// Restore a captured state into this trainer (params + optimizer,
    /// including the streaming-session replay) and hand back the batch
    /// RNG positioned at the state's data cursor.
    fn apply_state(&mut self, st: &TrainState) -> Result<Rng, TrainError> {
        if st.params.len() != self.params.len() {
            return Err(TrainError::Mismatch(format!(
                "checkpoint has {} params, model needs {}",
                st.params.len(),
                self.params.len()
            )));
        }
        match (&mut self.solver, &st.optimizer) {
            (TrainSolver::Ngd(ngd), OptimizerState::Ngd(ns)) => {
                ngd.restore_state(ns.clone()).map_err(|e| match e {
                    SolveError::BadInput(m) => TrainError::Mismatch(m),
                    other => TrainError::Solve(other),
                })?;
            }
            (TrainSolver::Sgd(sgd), OptimizerState::Sgd(ss)) => {
                sgd.restore_velocity(ss.velocity.clone());
            }
            _ => {
                return Err(TrainError::Mismatch(
                    "checkpoint optimizer kind does not match this run's optimizer".into(),
                ))
            }
        }
        self.params.copy_from_slice(&st.params);
        Ok(Rng::from_state(st.rng_words, st.rng_cached))
    }

    /// Restore the full training state from an explicit checkpoint file
    /// and arm the next [`Trainer::run`] to continue at the saved step.
    /// Returns the step the checkpoint was taken at.
    pub fn load_checkpoint(&mut self, path: &Path) -> Result<usize, TrainError> {
        let st = TrainState::load(path)?;
        let rng = self.apply_state(&st)?;
        self.start_step = st.step;
        self.resume_rng = Some(rng.state());
        self.stats.resumed_from = Some(st.step);
        Ok(st.step)
    }

    /// Startup recovery: scan `train.checkpoint_dir` for the newest
    /// loadable checkpoint, quarantining corrupt files (renamed
    /// `*.corrupt`, never loaded) and skipping healthy files from other
    /// format generations. Returns the resumed step, or `None` when no
    /// usable checkpoint exists (fresh start).
    pub fn resume_latest(&mut self) -> Result<Option<usize>, TrainError> {
        let dir = std::path::PathBuf::from(&self.cfg.train.checkpoint_dir);
        let scan = recover_latest(&dir)?;
        self.stats.quarantined += scan.quarantined.len();
        self.stats.version_skipped += scan.skipped_versions.len();
        let Some((st, _path)) = scan.state else { return Ok(None) };
        let rng = self.apply_state(&st)?;
        self.start_step = st.step;
        self.resume_rng = Some(rng.state());
        self.stats.resumed_from = Some(st.step);
        Ok(Some(st.step))
    }

    /// Run up to `train.steps` total steps (continuing from a resumed /
    /// previous position), logging
    /// `(step, loss, lambda, grad_norm, step_secs)` rows.
    pub fn run(&mut self, log: &mut MetricsLog) -> Result<TrainReport, TrainError> {
        self.run_inner(log, None)
    }

    /// Run at most `stop_after` steps, then return — the chaos
    /// harness's kill-at-a-step-boundary hook. The trainer's cursor and
    /// data stream stay armed, so a later `run` continues seamlessly
    /// (or the process "dies" and a fresh trainer resumes from disk).
    pub fn run_partial(
        &mut self,
        log: &mut MetricsLog,
        stop_after: usize,
    ) -> Result<TrainReport, TrainError> {
        self.run_inner(log, Some(stop_after))
    }

    fn run_inner(
        &mut self,
        log: &mut MetricsLog,
        stop_after: Option<usize>,
    ) -> Result<TrainReport, TrainError> {
        let cfg = self.cfg.clone();
        // Rollback rebuilds the batch iterator mid-run, which needs
        // `&mut self` — so the iterator borrows a local copy of the
        // token stream instead of `self.tokens`.
        let tokens = self.tokens.clone();
        let batch_rng = match self.resume_rng.take() {
            Some((s, cached)) => Rng::from_state(s, cached),
            None => Rng::seed_from(cfg.train.seed ^ 0x9E3779B97F4A7C15).fork(1),
        };
        let mut batches =
            BatchIter::new(&tokens, cfg.model.context, cfg.train.batch_size, batch_rng);
        let started = Instant::now();
        let mut initial_loss = f64::NAN;
        let mut final_loss = f64::NAN;

        // Sentinel bookkeeping (all local: rollback resets it).
        let sentinel = cfg.train.sentinel;
        let mut best_loss = f64::INFINITY;
        let mut bad_loss_streak = 0usize;
        let mut lambda_pinned_streak = 0usize;
        let mut rollbacks = 0usize;
        // Rollback target: the run start, then every saved checkpoint.
        let mut last_good = self.capture_state(self.start_step, batches.rng());

        let mut step = self.start_step;
        let mut executed = 0usize;
        while step < cfg.train.steps {
            if let Some(cap) = stop_after {
                if executed >= cap {
                    break;
                }
            }
            let t0 = Instant::now();
            let (contexts, targets) = batches.next_batch();
            let eval = self.eval_batch_parallel(&contexts, &targets);

            // --- numerical-health sentinel: pre-step checks ---
            let mut trip: Option<&'static str> = None;
            if sentinel {
                if !eval.loss.is_finite()
                    || eval.grad.iter().any(|g| !g.is_finite())
                    || eval.scores.as_slice().iter().any(|v| !v.is_finite())
                {
                    self.stats.nan_trips += 1;
                    trip = Some("non-finite loss/gradient/scores");
                } else {
                    if eval.loss < best_loss {
                        best_loss = eval.loss;
                    }
                    // Hysteresis: one noisy mini-batch resets nothing
                    // permanent — the streak has to survive
                    // `divergence_patience` consecutive steps.
                    if eval.loss > cfg.train.divergence_ratio * best_loss {
                        bad_loss_streak += 1;
                    } else {
                        bad_loss_streak = 0;
                    }
                    if bad_loss_streak >= cfg.train.divergence_patience {
                        self.stats.divergence_trips += 1;
                        trip = Some("loss diverged from its best");
                    }
                    if trip.is_none() {
                        if let TrainSolver::Ngd(ngd) = &self.solver {
                            if let Some(ceiling) = ngd.damping.runaway_threshold() {
                                if ngd.damping.lambda() >= ceiling {
                                    lambda_pinned_streak += 1;
                                } else {
                                    lambda_pinned_streak = 0;
                                }
                                if lambda_pinned_streak >= cfg.train.divergence_patience {
                                    self.stats.lambda_runaway_trips += 1;
                                    trip = Some("λ pinned at its LM ceiling");
                                }
                            }
                        }
                    }
                }
            }

            if trip.is_none() {
                if initial_loss.is_nan() {
                    initial_loss = eval.loss;
                }
                let lambda = match &mut self.solver {
                    TrainSolver::Ngd(ngd) => {
                        let report =
                            ngd.step(&mut self.params, &eval.scores, &eval.grad, eval.loss)?;
                        report.lambda
                    }
                    TrainSolver::Sgd(sgd) => {
                        sgd.step(&mut self.params, &eval.grad);
                        0.0
                    }
                };
                // --- post-step check: the update itself went non-finite ---
                if sentinel && self.params.iter().any(|p| !p.is_finite()) {
                    self.stats.nan_trips += 1;
                    trip = Some("non-finite parameters after update");
                } else {
                    final_loss = eval.loss;
                    let grad_norm = crate::linalg::mat::norm2(&eval.grad);
                    log.push(&[
                        step as f64,
                        eval.loss,
                        lambda,
                        grad_norm,
                        t0.elapsed().as_secs_f64(),
                    ]);
                    step += 1;
                    executed += 1;
                    if cfg.train.checkpoint_every > 0 && step % cfg.train.checkpoint_every == 0 {
                        let state = self.capture_state(step, batches.rng());
                        state
                            .save(&checkpoint_path(Path::new(&cfg.train.checkpoint_dir), step))?;
                        self.stats.checkpoints_saved += 1;
                        last_good = state;
                    }
                }
            }

            if let Some(reason) = trip {
                if rollbacks == cfg.train.max_rollbacks {
                    return Err(TrainError::Diverged {
                        step,
                        rollbacks,
                        detail: reason.to_string(),
                    });
                }
                rollbacks += 1;
                self.stats.rollbacks += 1;
                // Roll back to the last good state and escalate λ: a
                // rollback that restored the exact diverging trajectory
                // would diverge again identically.
                let rng = self.apply_state(&last_good)?;
                step = last_good.step;
                batches =
                    BatchIter::new(&tokens, cfg.model.context, cfg.train.batch_size, rng);
                if let TrainSolver::Ngd(ngd) = &mut self.solver {
                    ngd.damping.escalate(10.0);
                    self.stats.lambda_escalations += 1;
                }
                // The escalated state is the new rollback target.
                last_good = self.capture_state(step, batches.rng());
                best_loss = f64::INFINITY;
                bad_loss_streak = 0;
                lambda_pinned_streak = 0;
            }
        }

        // Arm continuation: a later `run`/`run_partial` on this trainer
        // picks up exactly where this segment stopped.
        self.start_step = step;
        self.resume_rng = Some(batches.rng().state());

        Ok(TrainReport {
            steps: step,
            params: self.model.num_params(),
            initial_loss,
            final_loss,
            final_bits_per_char: final_loss / std::f64::consts::LN_2,
            wall_secs: started.elapsed().as_secs_f64(),
            backend: self.backend_name.clone(),
            stats: self.stats.clone(),
        })
    }
}

/// Column names for the trainer's [`MetricsLog`].
pub const TRAIN_LOG_COLUMNS: &[&str] = &["step", "loss", "lambda", "grad_norm", "step_secs"];

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_config() -> Config {
        Config::from_toml_str(
            r#"
[model]
dim = 8
heads = 2
layers = 1
context = 8
mlp_hidden = 16

[train]
steps = 8
batch_size = 16
learning_rate = 0.3
corpus_len = 4000
seed = 11

[solver]
lambda = 0.01

[coordinator]
workers = 2
use_artifacts = false
"#,
            &[],
        )
        .unwrap()
    }

    #[test]
    fn ngd_training_descends() {
        let cfg = tiny_config();
        let mut trainer = Trainer::new(&cfg, OptimizerChoice::Ngd).unwrap();
        assert!(trainer.backend().starts_with("sharded"));
        let mut log = MetricsLog::new(TRAIN_LOG_COLUMNS);
        let report = trainer.run(&mut log).unwrap();
        assert_eq!(log.len(), 8);
        assert!(report.final_loss < report.initial_loss, "{report:?}");
        assert!(report.final_bits_per_char > 0.0);
        assert_eq!(report.stats, TrainStats::default(), "healthy run trips nothing");
    }

    #[test]
    fn parallel_eval_matches_serial() {
        let cfg = tiny_config();
        let trainer = Trainer::new(&cfg, OptimizerChoice::Ngd).unwrap();
        let rng = Rng::seed_from(99);
        let mut batches = BatchIter::new(&trainer.tokens, 8, 12, rng.fork(0));
        let (contexts, targets) = batches.next_batch();
        let par = trainer.eval_batch_parallel(&contexts, &targets);
        let ser = trainer.model.batch_eval(&trainer.params, &contexts, &targets);
        assert!((par.loss - ser.loss).abs() < 1e-12);
        for (a, b) in par.grad.iter().zip(&ser.grad) {
            assert!((a - b).abs() < 1e-10);
        }
        for i in 0..12 {
            for j in (0..trainer.model.num_params()).step_by(101) {
                assert!((par.scores[(i, j)] - ser.scores[(i, j)]).abs() < 1e-10);
            }
        }
    }

    #[test]
    fn checkpoint_roundtrip_through_trainer() {
        let mut cfg = tiny_config();
        let dir = std::env::temp_dir().join("dngd_trainer_ckpt_test");
        std::fs::remove_dir_all(&dir).ok();
        cfg.train.checkpoint_dir = dir.to_string_lossy().to_string();
        cfg.train.checkpoint_every = 4;
        cfg.train.steps = 4;
        let mut trainer = Trainer::new(&cfg, OptimizerChoice::Ngd).unwrap();
        let mut log = MetricsLog::new(TRAIN_LOG_COLUMNS);
        trainer.run(&mut log).unwrap();
        let ckpt_path = dir.join("step_4.ckpt");
        assert!(ckpt_path.exists());
        let saved_params = trainer.params.clone();
        // Scramble, then restore the full state.
        for p in trainer.params.iter_mut() {
            *p = 0.0;
        }
        let step = trainer.load_checkpoint(&ckpt_path).unwrap();
        assert_eq!(step, 4);
        assert_eq!(trainer.params, saved_params);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn kill_and_resume_is_bit_identical_classic() {
        // The kill-anywhere contract end to end, classic sharded-chol
        // mode: kill after 3 steps, resume a *fresh* trainer from the
        // latest checkpoint (step 2), rerun to completion — final
        // params must match the unfailed run bit for bit. The full
        // kill-boundary × mode matrix lives in tests/durability.rs.
        let mut cfg = tiny_config();
        let dir = std::env::temp_dir().join("dngd_trainer_kill_resume_test");
        std::fs::remove_dir_all(&dir).ok();
        cfg.train.checkpoint_dir = dir.to_string_lossy().to_string();
        cfg.train.checkpoint_every = 2;
        cfg.train.steps = 6;

        let mut reference = Trainer::new(&cfg, OptimizerChoice::Ngd).unwrap();
        let mut log = MetricsLog::new(TRAIN_LOG_COLUMNS);
        reference.run(&mut log).unwrap();

        std::fs::remove_dir_all(&dir).ok();
        let mut killed = Trainer::new(&cfg, OptimizerChoice::Ngd).unwrap();
        let mut log2 = MetricsLog::new(TRAIN_LOG_COLUMNS);
        killed.run_partial(&mut log2, 3).unwrap();
        drop(killed); // the "crash"

        let mut resumed = Trainer::new(&cfg, OptimizerChoice::Ngd).unwrap();
        let at = resumed.resume_latest().unwrap();
        assert_eq!(at, Some(2), "latest durable checkpoint is step 2");
        let mut log3 = MetricsLog::new(TRAIN_LOG_COLUMNS);
        let report = resumed.run(&mut log3).unwrap();
        assert_eq!(report.steps, 6);
        assert_eq!(report.stats.resumed_from, Some(2));
        for (j, (a, b)) in reference.params.iter().zip(&resumed.params).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "param {j} diverged after resume");
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn resume_latest_quarantines_corrupt_checkpoint() {
        let mut cfg = tiny_config();
        let dir = std::env::temp_dir().join("dngd_trainer_quarantine_test");
        std::fs::remove_dir_all(&dir).ok();
        cfg.train.checkpoint_dir = dir.to_string_lossy().to_string();
        cfg.train.checkpoint_every = 2;
        cfg.train.steps = 4;
        let mut trainer = Trainer::new(&cfg, OptimizerChoice::Ngd).unwrap();
        let mut log = MetricsLog::new(TRAIN_LOG_COLUMNS);
        trainer.run(&mut log).unwrap();
        // Corrupt the newest checkpoint (step 4); step 2 stays good.
        let p4 = dir.join("step_4.ckpt");
        let mut bytes = std::fs::read(&p4).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        std::fs::write(&p4, &bytes).unwrap();

        let mut resumed = Trainer::new(&cfg, OptimizerChoice::Ngd).unwrap();
        let at = resumed.resume_latest().unwrap();
        assert_eq!(at, Some(2), "must fall back to the older good checkpoint");
        assert_eq!(resumed.stats().quarantined, 1);
        assert!(!p4.exists(), "corrupt file renamed away");
        assert!(dir.join("step_4.ckpt.corrupt").exists());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn sentinel_aborts_after_rollback_budget() {
        // SGD at an infinite learning rate poisons the very first
        // update (±inf·grad, NaN where grad = 0) — the post-step param
        // guard trips deterministically; with nothing to escalate,
        // every rollback replays the same explosion until the budget
        // is spent — pinned counters.
        let mut cfg = tiny_config();
        cfg.train.learning_rate = f64::INFINITY;
        cfg.train.max_rollbacks = 2;
        cfg.train.steps = 6;
        let mut trainer = Trainer::new(&cfg, OptimizerChoice::Sgd).unwrap();
        let mut log = MetricsLog::new(TRAIN_LOG_COLUMNS);
        match trainer.run(&mut log) {
            Err(TrainError::Diverged { rollbacks, .. }) => assert_eq!(rollbacks, 2),
            other => panic!("expected Diverged, got {other:?}"),
        }
        let stats = trainer.stats();
        assert_eq!(stats.rollbacks, 2);
        assert_eq!(stats.nan_trips, 3, "initial trip + one per rollback replay");
        assert_eq!(stats.lambda_escalations, 0, "sgd has no λ to escalate");
    }

    #[test]
    fn lambda_runaway_sentinel_trips_with_hysteresis() {
        // Pin λ at the LM ceiling from step 0 (min = max = λ): the
        // runaway sentinel must wait out the patience window, then roll
        // back + escalate (a no-op at the ceiling), then abort when the
        // budget is spent — every counter deterministic.
        let mut cfg = tiny_config();
        cfg.solver.adaptive = true;
        cfg.solver.lambda = 0.5;
        cfg.solver.lambda_min = 0.5;
        cfg.solver.lambda_max = 0.5;
        cfg.train.divergence_patience = 2;
        cfg.train.max_rollbacks = 1;
        cfg.validate().unwrap();
        let mut trainer = Trainer::new(&cfg, OptimizerChoice::Ngd).unwrap();
        let mut log = MetricsLog::new(TRAIN_LOG_COLUMNS);
        match trainer.run(&mut log) {
            Err(TrainError::Diverged { rollbacks, detail, .. }) => {
                assert_eq!(rollbacks, 1);
                assert!(detail.contains("ceiling"), "{detail}");
            }
            other => panic!("expected Diverged, got {other:?}"),
        }
        let stats = trainer.stats();
        assert_eq!(stats.lambda_runaway_trips, 2);
        assert_eq!(stats.rollbacks, 1);
        assert_eq!(stats.lambda_escalations, 1);
        assert_eq!(stats.nan_trips, 0);
    }

    #[test]
    fn sentinel_off_restores_flowthrough() {
        // train.sentinel = false: the run neither trips nor rolls back
        // — non-finite values flow through as before PR 9.
        let mut cfg = tiny_config();
        cfg.train.sentinel = false;
        cfg.train.learning_rate = f64::INFINITY;
        cfg.train.steps = 3;
        let mut trainer = Trainer::new(&cfg, OptimizerChoice::Sgd).unwrap();
        let mut log = MetricsLog::new(TRAIN_LOG_COLUMNS);
        let report = trainer.run(&mut log).unwrap();
        assert_eq!(trainer.stats(), &TrainStats::default());
        assert_eq!(report.steps, 3);
        assert_eq!(log.len(), 3, "no step was withheld or rolled back");
    }

    #[test]
    fn streaming_window_training_descends() {
        // PR 5: solver.window routes the NGD optimizer through the
        // sliding-window streaming session (native chol owned-window
        // path at workers = 1); training still descends.
        let mut cfg = tiny_config();
        cfg.coordinator.workers = 1;
        cfg.solver.window = 48; // 3 batches of 16 in the window
        cfg.solver.refresh_every = 3; // exercise the drift backstop too
        cfg.validate().unwrap();
        let mut trainer = Trainer::new(&cfg, OptimizerChoice::Ngd).unwrap();
        assert_eq!(trainer.backend(), "native");
        let mut log = MetricsLog::new(TRAIN_LOG_COLUMNS);
        let report = trainer.run(&mut log).unwrap();
        assert!(report.final_loss < report.initial_loss, "{report:?}");
    }

    #[test]
    fn mixed_precision_training_descends_on_native_backend() {
        // PR 6: solver.precision = mixed pins the solve to the native
        // mixed-precision session (the sharded/PJRT backends are
        // f64-only) and the f32 factor actually runs.
        let mut cfg = tiny_config();
        cfg.solver.precision = crate::solver::Precision::Mixed;
        cfg.validate().unwrap();
        let mf0 = crate::solver::mixed_counters::mixed_factors();
        let mut trainer = Trainer::new(&cfg, OptimizerChoice::Ngd).unwrap();
        assert_eq!(trainer.backend(), "native", "mixed must not shard");
        let mut log = MetricsLog::new(TRAIN_LOG_COLUMNS);
        let report = trainer.run(&mut log).unwrap();
        assert!(report.final_loss < report.initial_loss, "{report:?}");
        assert!(
            crate::solver::mixed_counters::mixed_factors() > mf0,
            "training never exercised the f32 factor"
        );
    }

    #[test]
    fn non_chol_kind_routes_through_registry() {
        let mut cfg = tiny_config();
        cfg.solver.kind = crate::solver::SolverKind::Cg;
        cfg.train.steps = 3;
        let mut trainer = Trainer::new(&cfg, OptimizerChoice::Ngd).unwrap();
        // CG is not shardable: the registry hands back a serial native
        // solver even with workers > 1.
        assert_eq!(trainer.backend(), "native");
        let mut log = MetricsLog::new(TRAIN_LOG_COLUMNS);
        let report = trainer.run(&mut log).unwrap();
        assert!(report.final_loss.is_finite());
    }

    #[test]
    fn structured_kinds_route_through_registry() {
        // The PR-10 structured family has no sharded/artifact backend
        // either: the registry hands back the native structured solver
        // and training still converges to a finite loss.
        for kind in [
            crate::solver::SolverKind::BlockDiag,
            crate::solver::SolverKind::Hybrid,
        ] {
            let mut cfg = tiny_config();
            cfg.solver.kind = kind;
            cfg.solver.blocks = 2;
            cfg.train.steps = 3;
            // Model-scale score matrices carry no conditioning guarantee,
            // so keep the hybrid's inner tolerance above the f64
            // attainable-residual floor for whatever κ the run produces.
            cfg.solver.hybrid_tol = 1e-6;
            let mut trainer = Trainer::new(&cfg, OptimizerChoice::Ngd).unwrap();
            assert_eq!(trainer.backend(), "native", "{kind:?}");
            let mut log = MetricsLog::new(TRAIN_LOG_COLUMNS);
            let report = trainer.run(&mut log).unwrap();
            assert!(report.final_loss.is_finite(), "{kind:?}");
        }
    }

    #[test]
    fn sgd_baseline_runs() {
        let mut cfg = tiny_config();
        cfg.train.learning_rate = 0.5;
        let mut trainer = Trainer::new(&cfg, OptimizerChoice::Sgd).unwrap();
        let mut log = MetricsLog::new(TRAIN_LOG_COLUMNS);
        let report = trainer.run(&mut log).unwrap();
        assert!(report.final_loss.is_finite());
    }
}
