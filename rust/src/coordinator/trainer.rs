//! End-to-end NGD trainer: corpus → tokenizer → transformer → per-sample
//! scores (parallel over the batch) → damped solve (PJRT artifact,
//! sharded-native, or serial-native) → parameter update → metrics →
//! checkpoints.

use crate::checkpoint::Checkpoint;
use crate::config::Config;
use crate::data::{BatchIter, CharTokenizer, Rng, SyntheticCorpus};
use crate::linalg::Mat;
use crate::metrics::MetricsLog;
use crate::model::{BatchEval, Transformer, TransformerConfig};
use crate::ngd::{DampingSchedule, NaturalGradient, Sgd};
use crate::runtime::{ArtifactRegistry, Backend};
use crate::solver::{DampedSolver, Precision, SolveError, SolverKind, SolverRegistry};
use std::path::Path;
use std::time::Instant;

/// Which optimizer drives the run (the e2e example compares them).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OptimizerChoice {
    Ngd,
    Sgd,
}

/// Final report of a training run.
#[derive(Debug, Clone)]
pub struct TrainReport {
    pub steps: usize,
    pub params: usize,
    pub initial_loss: f64,
    pub final_loss: f64,
    /// Loss in bits/char (NLL / ln 2).
    pub final_bits_per_char: f64,
    pub wall_secs: f64,
    pub backend: String,
}

/// The end-to-end trainer.
pub struct Trainer {
    pub cfg: Config,
    pub model: Transformer,
    pub tokenizer: CharTokenizer,
    tokens: Vec<u32>,
    pub params: Vec<f64>,
    backend_name: String,
    solver: TrainSolver,
    eval_threads: usize,
}

enum TrainSolver {
    Ngd(NaturalGradient),
    Sgd(Sgd),
}

impl Trainer {
    /// Build a trainer from config: generates the corpus, fits the
    /// tokenizer, initializes the model, selects the solve backend.
    pub fn new(cfg: &Config, optimizer: OptimizerChoice) -> Result<Trainer, String> {
        let mut rng = Rng::seed_from(cfg.train.seed);
        let text = SyntheticCorpus::generate(cfg.train.corpus_len, &mut rng);
        let tokenizer = CharTokenizer::fit(&text);
        let tokens = tokenizer.encode(&text);

        let tcfg = TransformerConfig {
            vocab: tokenizer.vocab_size(),
            dim: cfg.model.dim,
            heads: cfg.model.heads,
            layers: cfg.model.layers,
            context: cfg.model.context,
            mlp_hidden: cfg.model.mlp_hidden,
        };
        tcfg.validate()?;
        let model = Transformer::new(tcfg);
        let params = model.init_params(&mut rng);
        let m = model.num_params();
        let n = cfg.train.batch_size;

        // Backend selection through the solver registry: PJRT artifact if
        // one matches (n, m) and artifacts are enabled; sharded-native
        // when workers > 1 and the kind is the shardable `chol`; otherwise
        // a registry-built serial solver of the configured kind with its
        // per-solver options (cg tolerance, budgets, threads, …).
        let registry = SolverRegistry::new(cfg.solver.options());
        // Mixed precision (PR 6) lives in the native chol/rvb sessions;
        // the sharded and PJRT backends are f64-only, so requesting it
        // pins the solve to the registry-built native solver rather than
        // silently dropping the mode.
        let mixed = cfg.solver.precision == Precision::Mixed;
        if mixed && cfg.solver.kind == SolverKind::Chol
            && (cfg.coordinator.workers > 1 || cfg.coordinator.use_artifacts)
        {
            eprintln!(
                "[trainer] solver.precision = mixed has no sharded/artifact backend; \
                 the solve runs on the native mixed-precision session"
            );
        }
        let shardable =
            cfg.solver.kind == SolverKind::Chol && cfg.coordinator.workers > 1 && !mixed;
        if cfg.solver.kind != SolverKind::Chol
            && (cfg.coordinator.workers > 1 || cfg.coordinator.use_artifacts)
        {
            // Not silently ignored (the config policy): only `chol` has a
            // sharded / PJRT-artifact backend today.
            eprintln!(
                "[trainer] solver.kind = {:?} has no sharded/artifact backend; \
                 coordinator.workers/use_artifacts apply to batch eval only — \
                 the solve runs serial native",
                cfg.solver.kind.as_str()
            );
        }
        let sharded = || -> (Box<dyn DampedSolver>, String) {
            (
                Box::new(super::ShardedCholSolver::new(
                    cfg.coordinator.workers,
                    cfg.coordinator.queue_depth,
                )),
                format!("sharded×{}", cfg.coordinator.workers),
            )
        };
        let (solver_box, backend_name): (Box<dyn DampedSolver>, String) =
            if cfg.coordinator.use_artifacts && cfg.solver.kind == SolverKind::Chol && !mixed {
                let reg = ArtifactRegistry::scan(Path::new(&cfg.coordinator.artifact_dir));
                match Backend::select(&reg, n, m, cfg.solver.threads) {
                    Backend::Pjrt(p) => (Box::new(p), "pjrt".to_string()),
                    Backend::Native(_) if shardable => sharded(),
                    Backend::Native(c) => (Box::new(c), "native".to_string()),
                }
            } else if shardable {
                sharded()
            } else {
                (registry.build(cfg.solver.kind), "native".to_string())
            };

        let solver = match optimizer {
            OptimizerChoice::Ngd => {
                let damping = if cfg.solver.adaptive {
                    // LM policy: grow λ when a step fails to improve the
                    // loss — stabilizes mini-batch NGD, where n ≪ m makes
                    // the per-batch Fisher noisy late in training.
                    DampingSchedule::LevenbergMarquardt {
                        lambda: cfg.solver.lambda,
                        grow: 2.0,
                        shrink: 0.9,
                        min: cfg.solver.lambda_min,
                        max: cfg.solver.lambda_max,
                    }
                } else if cfg.solver.lambda_decay < 1.0 {
                    DampingSchedule::ExponentialDecay {
                        initial: cfg.solver.lambda,
                        decay: cfg.solver.lambda_decay,
                        min: cfg.solver.lambda_min,
                    }
                } else {
                    DampingSchedule::Constant { lambda: cfg.solver.lambda }
                };
                let mut ngd = NaturalGradient::new(solver_box, damping, cfg.train.learning_rate)
                    .with_momentum(cfg.train.momentum);
                if cfg.train.trust_radius > 0.0 {
                    ngd = ngd.with_trust_radius(cfg.train.trust_radius);
                }
                if cfg.solver.window > 0 {
                    // Sliding-window streaming NGD (PR 5): the Fisher
                    // comes from the last `solver.window` score rows;
                    // each step rotates the batch through the
                    // chol/rvb owned-window session (O(knm + kn²),
                    // zero full-Gram SYRKs) or, for kinds without a
                    // rotatable factor, refactors the window cold.
                    ngd = ngd.with_window(cfg.solver.window, cfg.solver.refresh_every);
                }
                TrainSolver::Ngd(ngd)
            }
            OptimizerChoice::Sgd => TrainSolver::Sgd(
                Sgd::new(cfg.train.learning_rate).with_momentum(cfg.train.momentum),
            ),
        };

        Ok(Trainer {
            cfg: cfg.clone(),
            model,
            tokenizer,
            tokens,
            params,
            backend_name,
            solver,
            eval_threads: cfg.coordinator.workers.max(1),
        })
    }

    /// Backend label ("pjrt", "sharded×W", "native").
    pub fn backend(&self) -> &str {
        &self.backend_name
    }

    /// Batch evaluation parallelized over samples: per-sample backprop is
    /// embarrassingly parallel, so the batch is split across threads and
    /// the 1/√n-scaled rows are restitched with the global scaling.
    pub fn eval_batch_parallel(&self, contexts: &[Vec<u32>], targets: &[u32]) -> BatchEval {
        let n = contexts.len();
        let threads = self.eval_threads.min(n).max(1);
        if threads == 1 {
            return self.model.batch_eval(&self.params, contexts, targets);
        }
        let chunk = n.div_ceil(threads);
        let mut pieces: Vec<Option<BatchEval>> = Vec::new();
        for _ in 0..threads {
            pieces.push(None);
        }
        std::thread::scope(|scope| {
            let mut handles = Vec::new();
            for t in 0..threads {
                let lo = t * chunk;
                let hi = ((t + 1) * chunk).min(n);
                if lo >= hi {
                    break;
                }
                let model = &self.model;
                let params = &self.params;
                let ctx = &contexts[lo..hi];
                let tgt = &targets[lo..hi];
                handles.push((t, scope.spawn(move || model.batch_eval(params, ctx, tgt))));
            }
            for (t, h) in handles {
                pieces[t] = Some(h.join().expect("eval worker panicked"));
            }
        });
        // Merge: rows were scaled by 1/√n_sub inside each piece; rescale
        // to the global 1/√n. Loss/grad are weighted by n_sub/n.
        let m = self.model.num_params();
        let mut scores = Mat::zeros(n, m);
        let mut grad = vec![0.0; m];
        let mut loss = 0.0;
        let mut row = 0usize;
        for piece in pieces.into_iter().flatten() {
            let n_sub = piece.scores.rows();
            let rescale = (n_sub as f64).sqrt() / (n as f64).sqrt();
            for i in 0..n_sub {
                let src = piece.scores.row(i);
                let dst = scores.row_mut(row);
                for j in 0..m {
                    dst[j] = src[j] * rescale;
                }
                row += 1;
            }
            let w = n_sub as f64 / n as f64;
            loss += w * piece.loss;
            for j in 0..m {
                grad[j] += w * piece.grad[j];
            }
        }
        assert_eq!(row, n);
        BatchEval { loss, grad, scores }
    }

    /// Run the configured number of steps, logging
    /// `(step, loss, lambda, grad_norm, step_secs)` rows.
    pub fn run(&mut self, log: &mut MetricsLog) -> Result<TrainReport, SolveError> {
        let cfg = self.cfg.clone();
        let batch_rng = Rng::seed_from(cfg.train.seed ^ 0x9E3779B97F4A7C15);
        let mut batches =
            BatchIter::new(&self.tokens, cfg.model.context, cfg.train.batch_size, batch_rng.fork(1));
        let started = Instant::now();
        let mut initial_loss = f64::NAN;
        let mut final_loss = f64::NAN;

        for step in 0..cfg.train.steps {
            let t0 = Instant::now();
            let (contexts, targets) = batches.next_batch();
            let eval = self.eval_batch_parallel(&contexts, &targets);
            if step == 0 {
                initial_loss = eval.loss;
            }
            final_loss = eval.loss;

            let lambda = match &mut self.solver {
                TrainSolver::Ngd(ngd) => {
                    let report = ngd.step(&mut self.params, &eval.scores, &eval.grad, eval.loss)?;
                    report.lambda
                }
                TrainSolver::Sgd(sgd) => {
                    sgd.step(&mut self.params, &eval.grad);
                    0.0
                }
            };

            let grad_norm = crate::linalg::mat::norm2(&eval.grad);
            log.push(&[step as f64, eval.loss, lambda, grad_norm, t0.elapsed().as_secs_f64()]);

            if cfg.train.checkpoint_every > 0 && (step + 1) % cfg.train.checkpoint_every == 0 {
                self.save_checkpoint(step + 1)
                    .map_err(|e| SolveError::BadInput(format!("checkpoint: {e}")))?;
            }
        }

        Ok(TrainReport {
            steps: cfg.train.steps,
            params: self.model.num_params(),
            initial_loss,
            final_loss,
            final_bits_per_char: final_loss / std::f64::consts::LN_2,
            wall_secs: started.elapsed().as_secs_f64(),
            backend: self.backend_name.clone(),
        })
    }

    /// Save params (+ step marker) to `checkpoint_dir/step_{k}.ckpt`.
    pub fn save_checkpoint(&self, step: usize) -> Result<(), crate::checkpoint::CheckpointError> {
        let mut ck = Checkpoint::new();
        ck.insert("params", self.params.clone());
        ck.insert("step", vec![step as f64]);
        let path = Path::new(&self.cfg.train.checkpoint_dir).join(format!("step_{step}.ckpt"));
        ck.save(&path)
    }

    /// Restore params from a checkpoint file.
    pub fn load_checkpoint(&mut self, path: &Path) -> Result<usize, String> {
        let ck = Checkpoint::load(path).map_err(|e| e.to_string())?;
        let params = ck.get("params").ok_or("checkpoint missing `params`")?;
        if params.len() != self.params.len() {
            return Err(format!(
                "checkpoint has {} params, model needs {}",
                params.len(),
                self.params.len()
            ));
        }
        self.params.copy_from_slice(params);
        let step = ck.get("step").and_then(|s| s.first()).copied().unwrap_or(0.0);
        Ok(step as usize)
    }
}

/// Column names for the trainer's [`MetricsLog`].
pub const TRAIN_LOG_COLUMNS: &[&str] = &["step", "loss", "lambda", "grad_norm", "step_secs"];

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_config() -> Config {
        Config::from_toml_str(
            r#"
[model]
dim = 8
heads = 2
layers = 1
context = 8
mlp_hidden = 16

[train]
steps = 8
batch_size = 16
learning_rate = 0.3
corpus_len = 4000
seed = 11

[solver]
lambda = 0.01

[coordinator]
workers = 2
use_artifacts = false
"#,
            &[],
        )
        .unwrap()
    }

    #[test]
    fn ngd_training_descends() {
        let cfg = tiny_config();
        let mut trainer = Trainer::new(&cfg, OptimizerChoice::Ngd).unwrap();
        assert!(trainer.backend().starts_with("sharded"));
        let mut log = MetricsLog::new(TRAIN_LOG_COLUMNS);
        let report = trainer.run(&mut log).unwrap();
        assert_eq!(log.len(), 8);
        assert!(report.final_loss < report.initial_loss, "{report:?}");
        assert!(report.final_bits_per_char > 0.0);
    }

    #[test]
    fn parallel_eval_matches_serial() {
        let cfg = tiny_config();
        let trainer = Trainer::new(&cfg, OptimizerChoice::Ngd).unwrap();
        let rng = Rng::seed_from(99);
        let mut batches = BatchIter::new(&trainer.tokens, 8, 12, rng.fork(0));
        let (contexts, targets) = batches.next_batch();
        let par = trainer.eval_batch_parallel(&contexts, &targets);
        let ser = trainer.model.batch_eval(&trainer.params, &contexts, &targets);
        assert!((par.loss - ser.loss).abs() < 1e-12);
        for (a, b) in par.grad.iter().zip(&ser.grad) {
            assert!((a - b).abs() < 1e-10);
        }
        for i in 0..12 {
            for j in (0..trainer.model.num_params()).step_by(101) {
                assert!((par.scores[(i, j)] - ser.scores[(i, j)]).abs() < 1e-10);
            }
        }
    }

    #[test]
    fn checkpoint_roundtrip_through_trainer() {
        let mut cfg = tiny_config();
        let dir = std::env::temp_dir().join("dngd_trainer_ckpt_test");
        cfg.train.checkpoint_dir = dir.to_string_lossy().to_string();
        cfg.train.checkpoint_every = 4;
        cfg.train.steps = 4;
        let mut trainer = Trainer::new(&cfg, OptimizerChoice::Ngd).unwrap();
        let mut log = MetricsLog::new(TRAIN_LOG_COLUMNS);
        trainer.run(&mut log).unwrap();
        let ckpt_path = dir.join("step_4.ckpt");
        assert!(ckpt_path.exists());
        let saved_params = trainer.params.clone();
        // Scramble, then restore.
        for p in trainer.params.iter_mut() {
            *p = 0.0;
        }
        let step = trainer.load_checkpoint(&ckpt_path).unwrap();
        assert_eq!(step, 4);
        assert_eq!(trainer.params, saved_params);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn streaming_window_training_descends() {
        // PR 5: solver.window routes the NGD optimizer through the
        // sliding-window streaming session (native chol owned-window
        // path at workers = 1); training still descends.
        let mut cfg = tiny_config();
        cfg.coordinator.workers = 1;
        cfg.solver.window = 48; // 3 batches of 16 in the window
        cfg.solver.refresh_every = 3; // exercise the drift backstop too
        cfg.validate().unwrap();
        let mut trainer = Trainer::new(&cfg, OptimizerChoice::Ngd).unwrap();
        assert_eq!(trainer.backend(), "native");
        let mut log = MetricsLog::new(TRAIN_LOG_COLUMNS);
        let report = trainer.run(&mut log).unwrap();
        assert!(report.final_loss < report.initial_loss, "{report:?}");
    }

    #[test]
    fn mixed_precision_training_descends_on_native_backend() {
        // PR 6: solver.precision = mixed pins the solve to the native
        // mixed-precision session (the sharded/PJRT backends are
        // f64-only) and the f32 factor actually runs.
        let mut cfg = tiny_config();
        cfg.solver.precision = crate::solver::Precision::Mixed;
        cfg.validate().unwrap();
        let mf0 = crate::solver::mixed_counters::mixed_factors();
        let mut trainer = Trainer::new(&cfg, OptimizerChoice::Ngd).unwrap();
        assert_eq!(trainer.backend(), "native", "mixed must not shard");
        let mut log = MetricsLog::new(TRAIN_LOG_COLUMNS);
        let report = trainer.run(&mut log).unwrap();
        assert!(report.final_loss < report.initial_loss, "{report:?}");
        assert!(
            crate::solver::mixed_counters::mixed_factors() > mf0,
            "training never exercised the f32 factor"
        );
    }

    #[test]
    fn non_chol_kind_routes_through_registry() {
        let mut cfg = tiny_config();
        cfg.solver.kind = crate::solver::SolverKind::Cg;
        cfg.train.steps = 3;
        let mut trainer = Trainer::new(&cfg, OptimizerChoice::Ngd).unwrap();
        // CG is not shardable: the registry hands back a serial native
        // solver even with workers > 1.
        assert_eq!(trainer.backend(), "native");
        let mut log = MetricsLog::new(TRAIN_LOG_COLUMNS);
        let report = trainer.run(&mut log).unwrap();
        assert!(report.final_loss.is_finite());
    }

    #[test]
    fn sgd_baseline_runs() {
        let mut cfg = tiny_config();
        cfg.train.learning_rate = 0.5;
        let mut trainer = Trainer::new(&cfg, OptimizerChoice::Sgd).unwrap();
        let mut log = MetricsLog::new(TRAIN_LOG_COLUMNS);
        let report = trainer.run(&mut log).unwrap();
        assert!(report.final_loss.is_finite());
    }
}
