//! Distributed Algorithm 1 over the worker pool.

use super::pool::{Job, PoolError, WorkerPool};
use super::reduce::{reduce_vecs, tree_reduce_mats};
use super::shard::ShardPlan;
use crate::linalg::{cholesky, solve_lower, solve_lower_transpose, KernelConfig, Mat};
use crate::solver::{DampedSolver, SolveError};
use std::sync::mpsc::channel;
use std::sync::Arc;

/// Sharded Cholesky solver: the paper's Algorithm 1 with the O(n²m) and
/// O(nm) stages fanned out across workers and only n-sized state crossing
/// thread boundaries.
pub struct ShardedCholSolver {
    pool: WorkerPool,
    workers: usize,
}

impl ShardedCholSolver {
    pub fn new(workers: usize, queue_depth: usize) -> ShardedCholSolver {
        ShardedCholSolver::with_kernel(workers, queue_depth, KernelConfig::serial())
    }

    /// Like [`ShardedCholSolver::new`] but with an explicit per-worker
    /// kernel configuration (each worker's Gram product may itself run
    /// threaded on the persistent kernel pool when workers ≪ cores).
    pub fn with_kernel(
        workers: usize,
        queue_depth: usize,
        kernel: KernelConfig,
    ) -> ShardedCholSolver {
        ShardedCholSolver {
            pool: WorkerPool::spawn_with_kernel(workers, queue_depth, kernel),
            workers,
        }
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Distribute column shards of `s` to the workers; returns the plan.
    fn distribute(&self, s: &Mat) -> Result<ShardPlan, PoolError> {
        let plan = ShardPlan::balanced(s.cols(), self.workers);
        for (w, &(c0, c1)) in plan.ranges.iter().enumerate() {
            self.pool.send(w, Job::SetShard(s.slice_cols(c0, c1)))?;
        }
        Ok(plan)
    }

    fn pool_err(e: PoolError) -> SolveError {
        SolveError::BadInput(format!("coordinator: {e}"))
    }

    /// Full distributed solve of `(SᵀS + λI) x = v`.
    pub fn solve_distributed(
        &self,
        s: &Mat,
        v: &[f64],
        lambda: f64,
    ) -> Result<Vec<f64>, SolveError> {
        assert_eq!(v.len(), s.cols());
        if lambda <= 0.0 {
            return Err(SolveError::BadInput(format!("damping λ must be > 0, got {lambda}")));
        }
        let plan = self.distribute(s).map_err(Self::pool_err)?;
        let w_count = plan.workers();

        // Phase 1: partial Grams, tree-reduced; leader adds λĨ + factors.
        let (gtx, grx) = channel();
        for w in 0..w_count {
            self.pool.send(w, Job::Gram { reply: gtx.clone() }).map_err(Self::pool_err)?;
        }
        drop(gtx);
        let mut parts = Vec::with_capacity(w_count);
        for _ in 0..w_count {
            let (_, part) = grx.recv().map_err(|_| Self::pool_err(PoolError::WorkerGone(0)))?;
            parts.push(part);
        }
        let mut w_mat = tree_reduce_mats(parts, 4);
        w_mat.add_diag(lambda);
        let l = cholesky(&w_mat)?;

        // Phase 2: partial matvecs u_k = S_k v_k, reduced on the leader.
        let (utx, urx) = channel();
        for (w, &(c0, c1)) in plan.ranges.iter().enumerate() {
            self.pool
                .send(w, Job::Matvec { v_k: v[c0..c1].to_vec(), reply: utx.clone() })
                .map_err(Self::pool_err)?;
        }
        drop(utx);
        let mut uparts = Vec::with_capacity(w_count);
        for _ in 0..w_count {
            let (_, part) = urx.recv().map_err(|_| Self::pool_err(PoolError::WorkerGone(0)))?;
            uparts.push(part);
        }
        let u = reduce_vecs(&uparts);

        // Phase 3: leader-local O(n²) triangular solves.
        let y = solve_lower(&l, &u);
        let z = Arc::new(solve_lower_transpose(&l, &y));

        // Phase 4: per-shard apply, gathered in shard order.
        let (xtx, xrx) = channel();
        for (w, &(c0, c1)) in plan.ranges.iter().enumerate() {
            self.pool
                .send(
                    w,
                    Job::Apply {
                        z: z.clone(),
                        v_k: v[c0..c1].to_vec(),
                        lambda,
                        reply: xtx.clone(),
                    },
                )
                .map_err(Self::pool_err)?;
        }
        drop(xtx);
        let mut pieces: Vec<Option<Vec<f64>>> = vec![None; w_count];
        for _ in 0..w_count {
            let (wid, x_k) = xrx.recv().map_err(|_| Self::pool_err(PoolError::WorkerGone(0)))?;
            pieces[wid] = Some(x_k);
        }
        let mut x = Vec::with_capacity(s.cols());
        for (w, piece) in pieces.into_iter().enumerate() {
            let piece = piece.ok_or_else(|| Self::pool_err(PoolError::MissingShard(w)))?;
            assert_eq!(piece.len(), plan.ranges[w].1 - plan.ranges[w].0);
            x.extend_from_slice(&piece);
        }
        Ok(x)
    }
}

impl DampedSolver for ShardedCholSolver {
    fn name(&self) -> &'static str {
        "chol-sharded"
    }

    fn solve(&self, s: &Mat, v: &[f64], lambda: f64) -> Result<Vec<f64>, SolveError> {
        self.solve_distributed(s, v, lambda)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;
    use crate::solver::{residual_norm, CholSolver};

    #[test]
    fn matches_serial_solver_various_topologies() {
        let mut rng = Rng::seed_from(430);
        for &(n, m, workers) in &[
            (8usize, 40usize, 1usize),
            (8, 40, 3),
            (16, 100, 4),
            (16, 100, 16),
            (5, 7, 12), // more workers than columns
        ] {
            let s = Mat::randn(n, m, &mut rng);
            let v: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
            let solver = ShardedCholSolver::new(workers, 2);
            let x = solver.solve_distributed(&s, &v, 0.05).unwrap();
            let serial = CholSolver::default().solve(&s, &v, 0.05).unwrap();
            for (a, b) in x.iter().zip(&serial) {
                assert!((a - b).abs() < 1e-9, "topology ({n},{m},{workers})");
            }
        }
    }

    #[test]
    fn reusable_across_solves() {
        let mut rng = Rng::seed_from(431);
        let solver = ShardedCholSolver::new(4, 2);
        for _ in 0..3 {
            let s = Mat::randn(10, 50, &mut rng);
            let v: Vec<f64> = (0..50).map(|_| rng.normal()).collect();
            let x = solver.solve_distributed(&s, &v, 0.1).unwrap();
            assert!(residual_norm(&s, &x, &v, 0.1) < 1e-8);
        }
    }

    #[test]
    fn property_agreement_random_topologies() {
        let mut rng = Rng::seed_from(432);
        for _ in 0..20 {
            let n = 2 + rng.below(12);
            let m = n + rng.below(60);
            let workers = 1 + rng.below(9);
            let s = Mat::randn(n, m, &mut rng);
            let v: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
            let solver = ShardedCholSolver::new(workers, 1 + rng.below(3));
            let x = solver.solve_distributed(&s, &v, 0.2).unwrap();
            let serial = CholSolver::default().solve(&s, &v, 0.2).unwrap();
            for (a, b) in x.iter().zip(&serial) {
                assert!((a - b).abs() < 1e-9);
            }
        }
    }
}
