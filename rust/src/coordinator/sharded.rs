//! Distributed Algorithm 1 over the worker pool.
//!
//! Session note (PR 2): [`ShardedFactor`] stages the distributed solve —
//! shard distribution and the tree-reduced Gram happen once per score
//! matrix; λ-resweeps refactor the cached n×n Gram on the leader in
//! O(n³) with **zero** worker traffic, and each right-hand side costs one
//! matvec/apply round-trip (phases 2–4).

use super::pool::{Job, PoolError, WorkerPool};
use super::reduce::{reduce_vecs, tree_reduce_mats};
use super::shard::ShardPlan;
use crate::linalg::{
    solve_lower, solve_lower_multi_threaded, solve_lower_transpose,
    solve_lower_transpose_multi_threaded, KernelConfig, Mat,
};
use crate::solver::session::{check_lambda, refactor_damped, undamped_err};
use crate::solver::{DampedSolver, Factorization, SolveError};
use std::sync::mpsc::channel;
use std::sync::Arc;

/// Sharded Cholesky solver: the paper's Algorithm 1 with the O(n²m) and
/// O(nm) stages fanned out across workers and only n-sized state crossing
/// thread boundaries.
pub struct ShardedCholSolver {
    pool: WorkerPool,
    workers: usize,
    /// Kernel configuration shared by the workers' Gram products and the
    /// leader's local O(n³) work (the λ-resweep refactor) — since PR 3 a
    /// resweep runs the lookahead-threaded Cholesky with this thread
    /// count instead of silently dropping to serial.
    kernel: KernelConfig,
}

impl ShardedCholSolver {
    pub fn new(workers: usize, queue_depth: usize) -> ShardedCholSolver {
        ShardedCholSolver::with_kernel(workers, queue_depth, KernelConfig::serial())
    }

    /// Like [`ShardedCholSolver::new`] but with an explicit per-worker
    /// kernel configuration (each worker's Gram product may itself run
    /// threaded on the persistent kernel pool when workers ≪ cores).
    pub fn with_kernel(
        workers: usize,
        queue_depth: usize,
        kernel: KernelConfig,
    ) -> ShardedCholSolver {
        ShardedCholSolver {
            pool: WorkerPool::spawn_with_kernel(workers, queue_depth, kernel),
            workers,
            kernel,
        }
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Distribute column shards of `s` to the workers; returns the plan.
    fn distribute(&self, s: &Mat) -> Result<ShardPlan, PoolError> {
        let plan = ShardPlan::balanced(s.cols(), self.workers);
        for (w, &(c0, c1)) in plan.ranges.iter().enumerate() {
            self.pool.send(w, Job::SetShard(s.slice_cols(c0, c1)))?;
        }
        Ok(plan)
    }

    fn pool_err(e: PoolError) -> SolveError {
        SolveError::BadInput(format!("coordinator: {e}"))
    }

    /// Phase 1: partial Grams on the workers, tree-reduced on the leader
    /// (un-damped — the session adds λ when refactoring).
    fn gram_reduced(&self, plan: &ShardPlan) -> Result<Mat, SolveError> {
        let w_count = plan.workers();
        let (gtx, grx) = channel();
        for w in 0..w_count {
            self.pool.send(w, Job::Gram { reply: gtx.clone() }).map_err(Self::pool_err)?;
        }
        drop(gtx);
        let mut parts = Vec::with_capacity(w_count);
        for _ in 0..w_count {
            let (_, part) = grx.recv().map_err(|_| Self::pool_err(PoolError::WorkerGone(0)))?;
            parts.push(part);
        }
        Ok(tree_reduce_mats(parts, 4))
    }

    /// Phases 2–4 for one right-hand side against a leader-local factor.
    fn apply_phases(
        &self,
        plan: &ShardPlan,
        l: &Mat,
        v: &[f64],
        lambda: f64,
        x: &mut [f64],
    ) -> Result<(), SolveError> {
        let w_count = plan.workers();

        // Phase 2: partial matvecs u_k = S_k v_k, reduced on the leader.
        let (utx, urx) = channel();
        for (w, &(c0, c1)) in plan.ranges.iter().enumerate() {
            self.pool
                .send(w, Job::Matvec { v_k: v[c0..c1].to_vec(), reply: utx.clone() })
                .map_err(Self::pool_err)?;
        }
        drop(utx);
        let mut uparts = Vec::with_capacity(w_count);
        for _ in 0..w_count {
            let (_, part) = urx.recv().map_err(|_| Self::pool_err(PoolError::WorkerGone(0)))?;
            uparts.push(part);
        }
        let u = reduce_vecs(&uparts);

        // Phase 3: leader-local O(n²) triangular solves.
        let y = solve_lower(l, &u);
        let z = Arc::new(solve_lower_transpose(l, &y));

        // Phase 4: per-shard apply, gathered in shard order.
        let (xtx, xrx) = channel();
        for (w, &(c0, c1)) in plan.ranges.iter().enumerate() {
            self.pool
                .send(
                    w,
                    Job::Apply {
                        z: z.clone(),
                        v_k: v[c0..c1].to_vec(),
                        lambda,
                        reply: xtx.clone(),
                    },
                )
                .map_err(Self::pool_err)?;
        }
        drop(xtx);
        let mut pieces: Vec<Option<Vec<f64>>> = vec![None; w_count];
        for _ in 0..w_count {
            let (wid, x_k) = xrx.recv().map_err(|_| Self::pool_err(PoolError::WorkerGone(0)))?;
            pieces[wid] = Some(x_k);
        }
        for (w, piece) in pieces.into_iter().enumerate() {
            let piece = piece.ok_or_else(|| Self::pool_err(PoolError::MissingShard(w)))?;
            let (c0, c1) = plan.ranges[w];
            assert_eq!(piece.len(), c1 - c0);
            x[c0..c1].copy_from_slice(&piece);
        }
        Ok(())
    }

    /// Batched phases 2–4 for a k-RHS block (PR-5 bugfix): the default
    /// `solve_many` inherited by [`ShardedFactor`] paid k full worker
    /// round-trips (k× Matvec/Apply message latency); this sends each
    /// worker its whole column panel once per phase —
    /// [`Job::MatvecMany`] / [`Job::ApplyMany`] — so a k-RHS solve is
    /// one matvec round-trip, one leader-local blocked TRSM pair, and
    /// one apply round-trip, mirroring the serial session's panel path.
    fn apply_phases_many(
        &self,
        plan: &ShardPlan,
        l: &Mat,
        vs: &Mat,
        lambda: f64,
    ) -> Result<Mat, SolveError> {
        let w_count = plan.workers();
        let (k, m) = vs.shape();

        // Phase 2 (batched): U = Σ_k S_k·V_kᵀ, reduced on the leader.
        let (utx, urx) = channel();
        for (w, &(c0, c1)) in plan.ranges.iter().enumerate() {
            self.pool
                .send(w, Job::MatvecMany { v_k: vs.slice_cols(c0, c1), reply: utx.clone() })
                .map_err(Self::pool_err)?;
        }
        drop(utx);
        let mut uparts = Vec::with_capacity(w_count);
        for _ in 0..w_count {
            let (_, part) = urx.recv().map_err(|_| Self::pool_err(PoolError::WorkerGone(0)))?;
            uparts.push(part);
        }
        let u = tree_reduce_mats(uparts, 4);

        // Phase 3: leader-local blocked TRSM pair on the kernel pool.
        let threads = self.kernel.threads;
        let z = Arc::new(self.kernel.run(|| {
            let y = solve_lower_multi_threaded(l, &u, threads);
            solve_lower_transpose_multi_threaded(l, &y, threads)
        }));

        // Phase 4 (batched): per-shard apply, stitched in shard order.
        let (xtx, xrx) = channel();
        for (w, &(c0, c1)) in plan.ranges.iter().enumerate() {
            self.pool
                .send(
                    w,
                    Job::ApplyMany {
                        z: z.clone(),
                        v_k: vs.slice_cols(c0, c1),
                        lambda,
                        reply: xtx.clone(),
                    },
                )
                .map_err(Self::pool_err)?;
        }
        drop(xtx);
        let mut pieces: Vec<Option<Mat>> = vec![None; w_count];
        for _ in 0..w_count {
            let (wid, x_k) = xrx.recv().map_err(|_| Self::pool_err(PoolError::WorkerGone(0)))?;
            pieces[wid] = Some(x_k);
        }
        let mut x = Mat::zeros(k, m);
        for (w, piece) in pieces.into_iter().enumerate() {
            let piece = piece.ok_or_else(|| Self::pool_err(PoolError::MissingShard(w)))?;
            let (c0, c1) = plan.ranges[w];
            assert_eq!(piece.shape(), (k, c1 - c0));
            for r in 0..k {
                x.row_mut(r)[c0..c1].copy_from_slice(piece.row(r));
            }
        }
        Ok(x)
    }

    /// Drain the worker pool, returning per-worker processed-job counts
    /// (tests use this to pin message-count properties, e.g. that a
    /// k-RHS `solve_many` costs one round-trip, not k).
    pub fn shutdown(self) -> Vec<u64> {
        self.pool.shutdown()
    }

    /// Full distributed solve of `(SᵀS + λI) x = v` — one-shot shim over
    /// the [`ShardedFactor`] session.
    pub fn solve_distributed(
        &self,
        s: &Mat,
        v: &[f64],
        lambda: f64,
    ) -> Result<Vec<f64>, SolveError> {
        let mut fact = self.factor(s, lambda)?;
        fact.solve(v)
    }
}

/// Distributed session: shard distribution + reduced Gram staged once,
/// λ-resweeps leader-local, each RHS one pipelined worker round-trip.
///
/// Sessions on one [`ShardedCholSolver`] share its worker pool (workers
/// hold one shard set at a time), so interleaving two *live* sessions on
/// the same solver is not supported — the same sequential-use contract
/// the one-shot path always had.
pub struct ShardedFactor<'s> {
    solver: &'s ShardedCholSolver,
    s: &'s Mat,
    lambda: f64,
    plan: Option<ShardPlan>,
    /// Tree-reduced un-damped Gram, cached on the leader.
    gram: Option<Mat>,
    l: Option<Mat>,
}

impl<'s> ShardedFactor<'s> {
    fn new(solver: &'s ShardedCholSolver, s: &'s Mat) -> Self {
        ShardedFactor { solver, s, lambda: 0.0, plan: None, gram: None, l: None }
    }
}

impl Factorization for ShardedFactor<'_> {
    fn name(&self) -> &'static str {
        "chol-sharded"
    }

    fn dim(&self) -> usize {
        self.s.cols()
    }

    fn lambda(&self) -> f64 {
        self.lambda
    }

    fn redamp(&mut self, lambda: f64) -> Result<(), SolveError> {
        check_lambda(lambda)?;
        if self.plan.is_none() {
            let plan = self.solver.distribute(self.s).map_err(ShardedCholSolver::pool_err)?;
            self.gram = Some(self.solver.gram_reduced(&plan)?);
            self.plan = Some(plan);
        }
        match refactor_damped(self.gram.as_ref().unwrap(), lambda, self.solver.kernel.threads) {
            Ok(l) => {
                self.l = Some(l);
                self.lambda = lambda;
                Ok(())
            }
            Err(e) => {
                self.l = None;
                self.lambda = 0.0;
                Err(e)
            }
        }
    }

    fn solve_into(&mut self, v: &[f64], x: &mut [f64]) -> Result<(), SolveError> {
        let m = self.s.cols();
        assert_eq!(v.len(), m, "v must be m-dimensional");
        assert_eq!(x.len(), m, "x must be m-dimensional");
        let (Some(plan), Some(l)) = (self.plan.as_ref(), self.l.as_ref()) else {
            return Err(undamped_err());
        };
        self.solver.apply_phases(plan, l, v, self.lambda, x)
    }

    /// Batched k-RHS distributed solve: one `MatvecMany` round-trip,
    /// one leader-local blocked TRSM pair, one `ApplyMany` round-trip —
    /// instead of the k× message latency the inherited default paid
    /// (the PR-5 sharded bugfix; message accounting pinned in
    /// `coordinator_integration.rs`).
    fn solve_many(&mut self, vs: &Mat) -> Result<Mat, SolveError> {
        assert_eq!(vs.cols(), self.s.cols(), "each row of vs must be m-dimensional");
        let (Some(plan), Some(l)) = (self.plan.as_ref(), self.l.as_ref()) else {
            return Err(undamped_err());
        };
        self.solver.apply_phases_many(plan, l, vs, self.lambda)
    }
}

impl DampedSolver for ShardedCholSolver {
    fn name(&self) -> &'static str {
        "chol-sharded"
    }

    fn begin<'s>(&'s self, s: &'s Mat) -> Box<dyn Factorization + 's> {
        Box::new(ShardedFactor::new(self, s))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;
    use crate::solver::{residual_norm, CholSolver};

    #[test]
    fn matches_serial_solver_various_topologies() {
        let mut rng = Rng::seed_from(430);
        for &(n, m, workers) in &[
            (8usize, 40usize, 1usize),
            (8, 40, 3),
            (16, 100, 4),
            (16, 100, 16),
            (5, 7, 12), // more workers than columns
        ] {
            let s = Mat::randn(n, m, &mut rng);
            let v: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
            let solver = ShardedCholSolver::new(workers, 2);
            let x = solver.solve_distributed(&s, &v, 0.05).unwrap();
            let serial = CholSolver::default().solve(&s, &v, 0.05).unwrap();
            for (a, b) in x.iter().zip(&serial) {
                assert!((a - b).abs() < 1e-9, "topology ({n},{m},{workers})");
            }
        }
    }

    #[test]
    fn reusable_across_solves() {
        let mut rng = Rng::seed_from(431);
        let solver = ShardedCholSolver::new(4, 2);
        for _ in 0..3 {
            let s = Mat::randn(10, 50, &mut rng);
            let v: Vec<f64> = (0..50).map(|_| rng.normal()).collect();
            let x = solver.solve_distributed(&s, &v, 0.1).unwrap();
            assert!(residual_norm(&s, &x, &v, 0.1) < 1e-8);
        }
    }

    #[test]
    fn session_amortizes_rhs_and_resweeps() {
        let mut rng = Rng::seed_from(433);
        let solver = ShardedCholSolver::new(3, 2);
        let s = Mat::randn(12, 70, &mut rng);
        let mut fact = solver.factor(&s, 0.2).unwrap();
        for _ in 0..3 {
            let v: Vec<f64> = (0..70).map(|_| rng.normal()).collect();
            let x = fact.solve(&v).unwrap();
            let serial = CholSolver::default().solve(&s, &v, 0.2).unwrap();
            for (a, b) in x.iter().zip(&serial) {
                assert!((a - b).abs() < 1e-9);
            }
        }
        // λ-resweep: leader-local refactor, then solve again.
        fact.redamp(0.002).unwrap();
        let v: Vec<f64> = (0..70).map(|_| rng.normal()).collect();
        let x = fact.solve(&v).unwrap();
        assert!(residual_norm(&s, &x, &v, 0.002) < 1e-8);
    }

    #[test]
    fn property_agreement_random_topologies() {
        let mut rng = Rng::seed_from(432);
        for _ in 0..20 {
            let n = 2 + rng.below(12);
            let m = n + rng.below(60);
            let workers = 1 + rng.below(9);
            let s = Mat::randn(n, m, &mut rng);
            let v: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
            let solver = ShardedCholSolver::new(workers, 1 + rng.below(3));
            let x = solver.solve_distributed(&s, &v, 0.2).unwrap();
            let serial = CholSolver::default().solve(&s, &v, 0.2).unwrap();
            for (a, b) in x.iter().zip(&serial) {
                assert!((a - b).abs() < 1e-9);
            }
        }
    }
}
