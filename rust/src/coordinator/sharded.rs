//! Distributed Algorithm 1 over a pluggable shard transport.
//!
//! Session note (PR 2): [`ShardedFactor`] stages the distributed solve —
//! shard distribution and the tree-reduced Gram happen once per score
//! matrix; λ-resweeps refactor the cached n×n Gram on the leader in
//! O(n³) with **zero** worker traffic, and each k-RHS block costs one
//! matvec/apply round-trip (phases 2–4, batched panels).
//!
//! Since PR 7 the workers sit behind a
//! [`ShardTransport`](crate::serve::transport::ShardTransport) — the
//! in-process channel pool or the Unix-socket transport — and every
//! shard is keyed by session id, so **multiple live sessions coexist**
//! on one solver (the serving layer's multi-tenant mode; the old
//! one-live-session contract is gone). Replies are collected in worker
//! order, which makes the tree reduction order — and therefore the
//! result bits — independent of worker arrival timing.
//!
//! Error taxonomy (PR 7): transport faults surface as
//! [`SolveError::Backend`] with the transport's retryable/fatal split,
//! and a failed call leaves the session's cached plan/Gram intact — a
//! full queue or dead worker no longer poisons the session.

use super::reduce::tree_reduce_mats;
use super::shard::ShardPlan;
use crate::linalg::gemm::gemm_nt_threaded;
use crate::linalg::{
    solve_lower_multi_threaded, solve_lower_transpose_multi_threaded, KernelConfig, Mat,
};
use crate::serve::transport::{
    ChannelTransport, ShardRequest, ShardResponse, ShardTransport, TransportError,
};
use crate::solver::session::{check_lambda, refactor_damped, undamped_err};
use crate::solver::{DampedSolver, Factorization, SolveError};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Sharded Cholesky solver: the paper's Algorithm 1 with the O(n²m) and
/// O(nm) stages fanned out across workers and only n-sized state crossing
/// worker boundaries.
pub struct ShardedCholSolver {
    transport: Box<dyn ShardTransport>,
    workers: usize,
    /// Kernel configuration shared by the workers' Gram products and the
    /// leader's local O(n³) work (the λ-resweep refactor) — since PR 3 a
    /// resweep runs the lookahead-threaded Cholesky with this thread
    /// count instead of silently dropping to serial.
    kernel: KernelConfig,
    next_sid: AtomicU64,
}

impl ShardedCholSolver {
    pub fn new(workers: usize, queue_depth: usize) -> ShardedCholSolver {
        ShardedCholSolver::with_kernel(workers, queue_depth, KernelConfig::serial())
    }

    /// Like [`ShardedCholSolver::new`] but with an explicit per-worker
    /// kernel configuration (each worker's Gram product may itself run
    /// threaded on the persistent kernel pool when workers ≪ cores).
    pub fn with_kernel(
        workers: usize,
        queue_depth: usize,
        kernel: KernelConfig,
    ) -> ShardedCholSolver {
        ShardedCholSolver::with_transport(
            Box::new(ChannelTransport::spawn(workers, queue_depth, kernel)),
            kernel,
        )
    }

    /// Run Algorithm 1 over an arbitrary transport (PR 7) — the channel
    /// pool and the Unix-socket transport produce bit-identical solves
    /// (see `rust/tests/serving.rs`).
    pub fn with_transport(
        transport: Box<dyn ShardTransport>,
        kernel: KernelConfig,
    ) -> ShardedCholSolver {
        let workers = transport.workers();
        ShardedCholSolver { transport, workers, kernel, next_sid: AtomicU64::new(0) }
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Which transport backs this solver (`"channels"` / `"socket"`).
    pub fn transport_name(&self) -> &'static str {
        self.transport.name()
    }

    /// Open a streaming sliding-window session that **owns** its window
    /// (unlike [`DampedSolver::begin`], which borrows the score matrix).
    /// Supports the PR-5 `update_rows`/`refresh` rotation distributed
    /// across the workers; used by the serving layer, where sessions
    /// outlive any one request.
    pub fn window_session(solver: &Arc<ShardedCholSolver>, window: Mat) -> ShardedWindowSession {
        let sid = solver.alloc_sid();
        ShardedWindowSession {
            solver: solver.clone(),
            window,
            sid,
            st: ShardedState::new(),
        }
    }

    /// Fault injection for tests: crash worker `w` (it exits without
    /// replying; in-flight and future requests fail with the fatal
    /// [`SolveError::Backend`]). Blocks until the death is observable.
    pub fn kill_worker(&self, w: usize) {
        if let Ok(t) = self.transport.request(w, ShardRequest::Die) {
            let _ = t.wait();
        }
    }

    /// Fault injection for tests: make worker `w` a straggler for `ms`
    /// milliseconds (fire-and-forget).
    pub fn stall_worker(&self, w: usize, ms: u64) {
        if let Ok(t) = self.transport.request(w, ShardRequest::Stall { ms }) {
            drop(t);
        }
    }

    /// Liveness probe for worker `w`: one bounded `Ping` round trip.
    /// `false` means dead or wedged past `timeout` — candidates for
    /// [`ShardedCholSolver::recover_worker`].
    pub fn probe_worker(&self, w: usize, timeout: std::time::Duration) -> bool {
        self.transport.probe(w, timeout)
    }

    /// Respawn (channels) or reconnect (socket) dead worker `w`. The
    /// revived worker holds **no shards**: every session that had state
    /// on it must be re-staged before its next request, which the
    /// serving layer does by re-materializing the session from its
    /// durable record (snapshot + rotation log).
    pub fn recover_worker(&self, w: usize) -> Result<(), SolveError> {
        self.transport.recover(w).map_err(Self::err)
    }

    /// Chaos hook: corrupt the wire framing toward worker `w` (no-op
    /// `false` on the in-process channel transport, which has no wire).
    pub fn inject_corrupt_frame(&self, w: usize) -> bool {
        self.transport.inject_corrupt_frame(w)
    }

    fn alloc_sid(&self) -> u64 {
        self.next_sid.fetch_add(1, Ordering::Relaxed) + 1
    }

    /// Transport fault → typed solver error, preserving the
    /// retryable/fatal split (the satellite-2 fix: callers can tell a
    /// back-off-and-retry condition from a dead backend).
    fn err(e: TransportError) -> SolveError {
        match e {
            TransportError::Retryable(d) => SolveError::Backend { retryable: true, detail: d },
            TransportError::Fatal(d) => SolveError::Backend { retryable: false, detail: d },
            e @ TransportError::FrameTooLarge { .. } => {
                SolveError::Backend { retryable: false, detail: e.to_string() }
            }
        }
    }

    fn expect_mat(r: Result<ShardResponse, TransportError>) -> Result<Mat, SolveError> {
        match r.map_err(Self::err)? {
            ShardResponse::Mat(m) => Ok(m),
            ShardResponse::Err(msg) => Err(SolveError::Backend { retryable: false, detail: msg }),
            other => Err(SolveError::Backend {
                retryable: false,
                detail: format!("unexpected worker response: {other:?}"),
            }),
        }
    }

    fn expect_ack(r: Result<ShardResponse, TransportError>) -> Result<(), SolveError> {
        match r.map_err(Self::err)? {
            ShardResponse::Ack => Ok(()),
            ShardResponse::Err(msg) => Err(SolveError::Backend { retryable: false, detail: msg }),
            other => Err(SolveError::Backend {
                retryable: false,
                detail: format!("unexpected worker response: {other:?}"),
            }),
        }
    }

    /// Distribute column shards of `s` to the workers under session
    /// `sid`; returns the plan.
    fn distribute(&self, sid: u64, s: &Mat) -> Result<ShardPlan, SolveError> {
        let plan = ShardPlan::balanced(s.cols(), self.workers);
        let mut tickets = Vec::with_capacity(self.workers);
        for (w, &(c0, c1)) in plan.ranges.iter().enumerate() {
            let req = ShardRequest::SetShard { sid, shard: s.slice_cols(c0, c1) };
            tickets.push(self.transport.request(w, req).map_err(Self::err)?);
        }
        for t in tickets {
            Self::expect_ack(t.wait())?;
        }
        Ok(plan)
    }

    /// Phase 1: partial Grams on the workers, tree-reduced on the leader
    /// in worker order (un-damped — the session adds λ when
    /// refactoring).
    fn gram_reduced(&self, sid: u64, plan: &ShardPlan) -> Result<Mat, SolveError> {
        let mut tickets = Vec::with_capacity(plan.workers());
        for w in 0..plan.workers() {
            tickets.push(self.transport.request(w, ShardRequest::Gram { sid }).map_err(Self::err)?);
        }
        let mut parts = Vec::with_capacity(tickets.len());
        for t in tickets {
            parts.push(Self::expect_mat(t.wait())?);
        }
        Ok(tree_reduce_mats(parts, 4))
    }

    /// Batched phases 2–4 for a k-RHS block: each worker gets its whole
    /// column panel once per phase — `MatvecMany` / `ApplyMany` — so a
    /// k-RHS solve is one matvec round-trip, one leader-local blocked
    /// TRSM pair, and one apply round-trip, mirroring the serial
    /// session's panel path (message accounting pinned in
    /// `coordinator_integration.rs`). Single-RHS solves route through
    /// the same path as a k=1 panel.
    fn apply_phases_many(
        &self,
        sid: u64,
        plan: &ShardPlan,
        l: &Mat,
        vs: &Mat,
        lambda: f64,
    ) -> Result<Mat, SolveError> {
        let (k, m) = vs.shape();

        // Phase 2 (batched): U = Σ_k S_k·V_kᵀ, reduced on the leader in
        // worker order (deterministic summation order).
        let mut tickets = Vec::with_capacity(plan.workers());
        for (w, &(c0, c1)) in plan.ranges.iter().enumerate() {
            let req = ShardRequest::MatvecMany { sid, v_k: vs.slice_cols(c0, c1) };
            tickets.push(self.transport.request(w, req).map_err(Self::err)?);
        }
        let mut uparts = Vec::with_capacity(tickets.len());
        for t in tickets {
            uparts.push(Self::expect_mat(t.wait())?);
        }
        let u = tree_reduce_mats(uparts, 4);

        // Phase 3: leader-local blocked TRSM pair on the kernel pool.
        let threads = self.kernel.threads;
        let z = self.kernel.run(|| {
            let y = solve_lower_multi_threaded(l, &u, threads);
            solve_lower_transpose_multi_threaded(l, &y, threads)
        });

        // Phase 4 (batched): per-shard apply, stitched in worker order.
        let mut tickets = Vec::with_capacity(plan.workers());
        for (w, &(c0, c1)) in plan.ranges.iter().enumerate() {
            let req = ShardRequest::ApplyMany {
                sid,
                z: z.clone(),
                v_k: vs.slice_cols(c0, c1),
                lambda,
            };
            tickets.push(self.transport.request(w, req).map_err(Self::err)?);
        }
        let mut x = Mat::zeros(k, m);
        for (w, t) in tickets.into_iter().enumerate() {
            let piece = Self::expect_mat(t.wait())?;
            let (c0, c1) = plan.ranges[w];
            assert_eq!(piece.shape(), (k, c1 - c0));
            for r in 0..k {
                x.row_mut(r)[c0..c1].copy_from_slice(piece.row(r));
            }
        }
        Ok(x)
    }

    /// Distributed PR-5 rotation: workers rotate their shards in place
    /// and return partial cross panels `P_k = S_kept,k·A_kᵀ`; the leader
    /// patches its cached Gram with the bordered block
    /// `[[G_kept, C], [Cᵀ, A·Aᵀ]]` (kept entries copied exactly — no
    /// accumulated drift) instead of paying a fresh O(n²m) Gram.
    /// Returns the patched Gram; the caller already rotated its window
    /// via [`rotate_rows_local`].
    fn rotate_gram_distributed(
        &self,
        sid: u64,
        plan: &ShardPlan,
        gram: &Mat,
        kept: &[usize],
        removed_sorted: &[usize],
        added: &Mat,
    ) -> Result<Mat, SolveError> {
        let n_kept = kept.len();
        let k_add = added.rows();

        let mut tickets = Vec::with_capacity(plan.workers());
        for (w, &(c0, c1)) in plan.ranges.iter().enumerate() {
            let req = ShardRequest::UpdateRows {
                sid,
                removed: removed_sorted.to_vec(),
                added_k: added.slice_cols(c0, c1),
            };
            tickets.push(self.transport.request(w, req).map_err(Self::err)?);
        }
        let mut parts = Vec::with_capacity(tickets.len());
        for t in tickets {
            parts.push(Self::expect_mat(t.wait())?);
        }
        // C = Σ_k P_k (n_kept × k_add), reduced in worker order.
        let cross = tree_reduce_mats(parts, 4);

        let n_new = n_kept + k_add;
        let mut new_gram = Mat::zeros(n_new, n_new);
        for (i, &ki) in kept.iter().enumerate() {
            for (j, &kj) in kept.iter().enumerate() {
                new_gram[(i, j)] = gram[(ki, kj)];
            }
        }
        for i in 0..n_kept {
            for j in 0..k_add {
                new_gram[(i, n_kept + j)] = cross[(i, j)];
                new_gram[(n_kept + j, i)] = cross[(i, j)];
            }
        }
        if k_add > 0 {
            // A·Aᵀ is k_add×k_add over the full m — leader-local, same
            // kernel config as the workers.
            let mut block = Mat::zeros(k_add, k_add);
            let threads = self.kernel.threads;
            self.kernel.run(|| gemm_nt_threaded(1.0, added, added, 0.0, &mut block, threads));
            for i in 0..k_add {
                for j in 0..k_add {
                    new_gram[(n_kept + i, n_kept + j)] = block[(i, j)];
                }
            }
        }
        Ok(new_gram)
    }

    /// Free session `sid`'s shards on every worker (blocking, errors
    /// ignored — teardown is best-effort on a degraded pool).
    fn drop_session(&self, sid: u64, plan: &ShardPlan) {
        let mut tickets = Vec::with_capacity(plan.workers());
        for w in 0..plan.workers() {
            if let Ok(t) = self.transport.request(w, ShardRequest::DropShard { sid }) {
                tickets.push(t);
            }
        }
        for t in tickets {
            let _ = t.wait();
        }
    }

    /// Drain the workers (explicit flush barrier — in-flight jobs finish
    /// first), stop them, and return per-worker processed-request counts
    /// (tests use this to pin message-count properties, e.g. that a
    /// k-RHS `solve_many` costs one round-trip, not k).
    pub fn shutdown(self) -> Vec<u64> {
        self.transport.shutdown()
    }

    /// Full distributed solve of `(SᵀS + λI) x = v` — one-shot shim over
    /// the [`ShardedFactor`] session.
    pub fn solve_distributed(
        &self,
        s: &Mat,
        v: &[f64],
        lambda: f64,
    ) -> Result<Vec<f64>, SolveError> {
        let mut fact = self.factor(s, lambda)?;
        fact.solve(v)
    }
}

/// λ-dependent distributed-session state shared by the borrowed
/// ([`ShardedFactor`]) and owned ([`ShardedWindowSession`]) variants.
struct ShardedState {
    lambda: f64,
    plan: Option<ShardPlan>,
    /// Tree-reduced un-damped Gram, cached on the leader.
    gram: Option<Mat>,
    l: Option<Mat>,
}

impl ShardedState {
    fn new() -> ShardedState {
        ShardedState { lambda: 0.0, plan: None, gram: None, l: None }
    }
}

/// Shared redamp: stage (distribute + reduce Gram) lazily on first
/// damp, then leader-local O(n³) refactor. Backend errors leave the
/// cached plan/Gram untouched so a transient fault is retryable;
/// only a non-PD factor clears the damped state (PR-2 semantics).
fn redamp_state(
    solver: &ShardedCholSolver,
    sid: u64,
    s: &Mat,
    st: &mut ShardedState,
    lambda: f64,
) -> Result<(), SolveError> {
    check_lambda(lambda)?;
    if st.plan.is_none() {
        let plan = solver.distribute(sid, s)?;
        st.gram = Some(solver.gram_reduced(sid, &plan)?);
        st.plan = Some(plan);
    }
    match refactor_damped(st.gram.as_ref().unwrap(), lambda, solver.kernel.threads) {
        Ok(l) => {
            st.l = Some(l);
            st.lambda = lambda;
            Ok(())
        }
        Err(e) => {
            st.l = None;
            st.lambda = 0.0;
            Err(e)
        }
    }
}

/// Shared k-RHS panel solve against the staged state.
fn panel_solve(
    solver: &ShardedCholSolver,
    sid: u64,
    st: &ShardedState,
    vs: &Mat,
) -> Result<Mat, SolveError> {
    let (Some(plan), Some(l)) = (st.plan.as_ref(), st.l.as_ref()) else {
        return Err(undamped_err());
    };
    solver.apply_phases_many(sid, plan, l, vs, st.lambda)
}

/// Validate a PR-5 rotation request against `window` and build the
/// rotated window leader-side. Returns `(sorted_removals, kept_rows,
/// new_window)`.
fn rotate_rows_local(
    window: &Mat,
    removed: &[usize],
    added: &Mat,
) -> Result<(Vec<usize>, Vec<usize>, Mat), SolveError> {
    let n = window.rows();
    let m = window.cols();
    let k_add = added.rows();
    if k_add > 0 && added.cols() != m {
        return Err(SolveError::BadInput(format!(
            "update_rows: added rows have {} cols, window has {m}",
            added.cols()
        )));
    }
    let mut rem: Vec<usize> = removed.to_vec();
    rem.sort_unstable();
    let before = rem.len();
    rem.dedup();
    if rem.len() != before {
        return Err(SolveError::BadInput("update_rows: duplicate removal index".into()));
    }
    if let Some(&bad) = rem.iter().find(|&&r| r >= n) {
        return Err(SolveError::BadInput(format!(
            "update_rows: removal index {bad} out of range (window has {n} rows)"
        )));
    }
    let mut rem_iter = rem.iter().copied().peekable();
    let kept: Vec<usize> = (0..n)
        .filter(|&r| {
            if rem_iter.peek() == Some(&r) {
                rem_iter.next();
                false
            } else {
                true
            }
        })
        .collect();
    let n_kept = kept.len();
    if n_kept + k_add == 0 {
        return Err(SolveError::BadInput("update_rows: rotation would empty the window".into()));
    }
    let mut new_window = Mat::zeros(n_kept + k_add, m);
    for (dst, &src) in kept.iter().enumerate() {
        new_window.row_mut(dst).copy_from_slice(window.row(src));
    }
    for r in 0..k_add {
        new_window.row_mut(n_kept + r).copy_from_slice(added.row(r));
    }
    Ok((rem, kept, new_window))
}

/// Distributed session borrowing its score matrix: shard distribution +
/// reduced Gram staged once, λ-resweeps leader-local, each k-RHS block
/// one pipelined worker round-trip. Shards are keyed by this session's
/// id, so any number of live sessions — including from concurrent
/// leader threads — share one solver.
pub struct ShardedFactor<'s> {
    solver: &'s ShardedCholSolver,
    s: &'s Mat,
    sid: u64,
    st: ShardedState,
}

impl<'s> ShardedFactor<'s> {
    fn new(solver: &'s ShardedCholSolver, s: &'s Mat) -> Self {
        let sid = solver.alloc_sid();
        ShardedFactor { solver, s, sid, st: ShardedState::new() }
    }
}

impl Drop for ShardedFactor<'_> {
    fn drop(&mut self) {
        if let Some(plan) = self.st.plan.take() {
            self.solver.drop_session(self.sid, &plan);
        }
    }
}

impl Factorization for ShardedFactor<'_> {
    fn name(&self) -> &'static str {
        "chol-sharded"
    }

    fn dim(&self) -> usize {
        self.s.cols()
    }

    fn lambda(&self) -> f64 {
        self.st.lambda
    }

    fn redamp(&mut self, lambda: f64) -> Result<(), SolveError> {
        redamp_state(self.solver, self.sid, self.s, &mut self.st, lambda)
    }

    fn solve_into(&mut self, v: &[f64], x: &mut [f64]) -> Result<(), SolveError> {
        let m = self.s.cols();
        assert_eq!(v.len(), m, "v must be m-dimensional");
        assert_eq!(x.len(), m, "x must be m-dimensional");
        // Single RHS = k=1 panel: one code path for every solve.
        let vs = Mat::from_vec(1, m, v.to_vec());
        let xs = panel_solve(self.solver, self.sid, &self.st, &vs)?;
        x.copy_from_slice(xs.row(0));
        Ok(())
    }

    fn solve_many(&mut self, vs: &Mat) -> Result<Mat, SolveError> {
        assert_eq!(vs.cols(), self.s.cols(), "each row of vs must be m-dimensional");
        panel_solve(self.solver, self.sid, &self.st, vs)
    }
}

/// Distributed streaming sliding-window session (PR 7): owns its window,
/// holds an `Arc` to the solver (so the serving layer can cache it past
/// any one request), and implements the PR-5 `update_rows`/`refresh`
/// rotation with the O(n²m) Gram rebuild replaced by worker-side shard
/// rotation + a bordered Gram patch (O(k·n·m/W) per worker).
pub struct ShardedWindowSession {
    solver: Arc<ShardedCholSolver>,
    window: Mat,
    sid: u64,
    st: ShardedState,
}

impl ShardedWindowSession {
    /// Rows currently in the window (changes under `update_rows`).
    pub fn window_rows(&self) -> usize {
        self.window.rows()
    }

    /// The live leader-side window. The serving layer's durable session
    /// records snapshot this at their refresh cadence (PR 8).
    pub fn window(&self) -> &Mat {
        &self.window
    }
}

impl Drop for ShardedWindowSession {
    fn drop(&mut self) {
        if let Some(plan) = self.st.plan.take() {
            self.solver.drop_session(self.sid, &plan);
        }
    }
}

impl Factorization for ShardedWindowSession {
    fn name(&self) -> &'static str {
        "chol-sharded-window"
    }

    fn dim(&self) -> usize {
        self.window.cols()
    }

    fn lambda(&self) -> f64 {
        self.st.lambda
    }

    fn redamp(&mut self, lambda: f64) -> Result<(), SolveError> {
        redamp_state(&self.solver, self.sid, &self.window, &mut self.st, lambda)
    }

    fn solve_into(&mut self, v: &[f64], x: &mut [f64]) -> Result<(), SolveError> {
        let m = self.window.cols();
        assert_eq!(v.len(), m, "v must be m-dimensional");
        assert_eq!(x.len(), m, "x must be m-dimensional");
        let vs = Mat::from_vec(1, m, v.to_vec());
        let xs = panel_solve(&self.solver, self.sid, &self.st, &vs)?;
        x.copy_from_slice(xs.row(0));
        Ok(())
    }

    fn solve_many(&mut self, vs: &Mat) -> Result<Mat, SolveError> {
        assert_eq!(vs.cols(), self.window.cols(), "each row of vs must be m-dimensional");
        panel_solve(&self.solver, self.sid, &self.st, vs)
    }

    fn update_rows(&mut self, removed: &[usize], added: &Mat) -> Result<(), SolveError> {
        let (rem, kept, new_window) = rotate_rows_local(&self.window, removed, added)?;
        let Some(plan) = self.st.plan.as_ref() else {
            // Never staged: nothing distributed to rotate yet.
            self.window = new_window;
            return Ok(());
        };
        let gram = self.st.gram.as_ref().expect("staged session always caches its Gram");
        let new_gram =
            self.solver.rotate_gram_distributed(self.sid, plan, gram, &kept, &rem, added)?;
        self.window = new_window;
        self.st.gram = Some(new_gram);
        if self.st.lambda > 0.0 {
            // Keep the session damped at the current λ (PR-5 contract).
            match refactor_damped(
                self.st.gram.as_ref().unwrap(),
                self.st.lambda,
                self.solver.kernel.threads,
            ) {
                Ok(l) => self.st.l = Some(l),
                Err(e) => {
                    // Window/Gram are already rotated; the caller's λ
                    // backoff can rescue the step (ngd semantics).
                    self.st.l = None;
                    return Err(e);
                }
            }
        }
        Ok(())
    }

    fn refresh(&mut self) -> Result<(), SolveError> {
        let Some(plan) = self.st.plan.as_ref() else {
            return Ok(());
        };
        let gram = self.solver.gram_reduced(self.sid, plan)?;
        self.st.gram = Some(gram);
        if self.st.lambda > 0.0 {
            match refactor_damped(
                self.st.gram.as_ref().unwrap(),
                self.st.lambda,
                self.solver.kernel.threads,
            ) {
                Ok(l) => self.st.l = Some(l),
                Err(e) => {
                    self.st.l = None;
                    return Err(e);
                }
            }
        }
        Ok(())
    }
}

impl DampedSolver for ShardedCholSolver {
    fn name(&self) -> &'static str {
        "chol-sharded"
    }

    fn begin<'s>(&'s self, s: &'s Mat) -> Box<dyn Factorization + 's> {
        Box::new(ShardedFactor::new(self, s))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;
    use crate::solver::{residual_norm, CholSolver};

    #[test]
    fn matches_serial_solver_various_topologies() {
        let mut rng = Rng::seed_from(430);
        for &(n, m, workers) in &[
            (8usize, 40usize, 1usize),
            (8, 40, 3),
            (16, 100, 4),
            (16, 100, 16),
            (5, 7, 12), // more workers than columns
        ] {
            let s = Mat::randn(n, m, &mut rng);
            let v: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
            let solver = ShardedCholSolver::new(workers, 2);
            let x = solver.solve_distributed(&s, &v, 0.05).unwrap();
            let serial = CholSolver::default().solve(&s, &v, 0.05).unwrap();
            for (a, b) in x.iter().zip(&serial) {
                assert!((a - b).abs() < 1e-9, "topology ({n},{m},{workers})");
            }
        }
    }

    #[test]
    fn reusable_across_solves() {
        let mut rng = Rng::seed_from(431);
        let solver = ShardedCholSolver::new(4, 2);
        for _ in 0..3 {
            let s = Mat::randn(10, 50, &mut rng);
            let v: Vec<f64> = (0..50).map(|_| rng.normal()).collect();
            let x = solver.solve_distributed(&s, &v, 0.1).unwrap();
            assert!(residual_norm(&s, &x, &v, 0.1) < 1e-8);
        }
    }

    #[test]
    fn session_amortizes_rhs_and_resweeps() {
        let mut rng = Rng::seed_from(433);
        let solver = ShardedCholSolver::new(3, 2);
        let s = Mat::randn(12, 70, &mut rng);
        let mut fact = solver.factor(&s, 0.2).unwrap();
        for _ in 0..3 {
            let v: Vec<f64> = (0..70).map(|_| rng.normal()).collect();
            let x = fact.solve(&v).unwrap();
            let serial = CholSolver::default().solve(&s, &v, 0.2).unwrap();
            for (a, b) in x.iter().zip(&serial) {
                assert!((a - b).abs() < 1e-9);
            }
        }
        // λ-resweep: leader-local refactor, then solve again.
        fact.redamp(0.002).unwrap();
        let v: Vec<f64> = (0..70).map(|_| rng.normal()).collect();
        let x = fact.solve(&v).unwrap();
        assert!(residual_norm(&s, &x, &v, 0.002) < 1e-8);
    }

    #[test]
    fn property_agreement_random_topologies() {
        let mut rng = Rng::seed_from(432);
        for _ in 0..20 {
            let n = 2 + rng.below(12);
            let m = n + rng.below(60);
            let workers = 1 + rng.below(9);
            let s = Mat::randn(n, m, &mut rng);
            let v: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
            let solver = ShardedCholSolver::new(workers, 1 + rng.below(3));
            let x = solver.solve_distributed(&s, &v, 0.2).unwrap();
            let serial = CholSolver::default().solve(&s, &v, 0.2).unwrap();
            for (a, b) in x.iter().zip(&serial) {
                assert!((a - b).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn two_live_sessions_interleave_without_clobbering() {
        // The PR-7 sid keying: two staged sessions on one solver must
        // not overwrite each other's worker shards (the old pool held
        // exactly one shard set and forbade this).
        let mut rng = Rng::seed_from(434);
        let solver = ShardedCholSolver::new(3, 4);
        let s1 = Mat::randn(10, 60, &mut rng);
        let s2 = Mat::randn(8, 60, &mut rng);
        let mut f1 = solver.factor(&s1, 0.1).unwrap();
        let mut f2 = solver.factor(&s2, 0.05).unwrap();
        for _ in 0..2 {
            let v: Vec<f64> = (0..60).map(|_| rng.normal()).collect();
            let x1 = f1.solve(&v).unwrap();
            let x2 = f2.solve(&v).unwrap();
            let r1 = CholSolver::default().solve(&s1, &v, 0.1).unwrap();
            let r2 = CholSolver::default().solve(&s2, &v, 0.05).unwrap();
            for (a, b) in x1.iter().zip(&r1) {
                assert!((a - b).abs() < 1e-9);
            }
            for (a, b) in x2.iter().zip(&r2) {
                assert!((a - b).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn window_session_rotation_matches_cold_factor() {
        let mut rng = Rng::seed_from(435);
        let solver = Arc::new(ShardedCholSolver::new(3, 4));
        let w0 = Mat::randn(12, 48, &mut rng);
        let added = Mat::randn(3, 48, &mut rng);
        let mut sess = ShardedCholSolver::window_session(&solver, w0.clone());
        sess.redamp(0.1).unwrap();
        sess.update_rows(&[0, 5, 7], &added).unwrap();
        assert_eq!(sess.window_rows(), 12);
        let v: Vec<f64> = (0..48).map(|_| rng.normal()).collect();
        let x = sess.solve(&v).unwrap();
        // Cold reference on the hand-rotated window.
        let kept: Vec<usize> = (0..12).filter(|r| ![0, 5, 7].contains(r)).collect();
        let mut rotated = Mat::zeros(12, 48);
        for (dst, &src) in kept.iter().enumerate() {
            rotated.row_mut(dst).copy_from_slice(w0.row(src));
        }
        for r in 0..3 {
            rotated.row_mut(9 + r).copy_from_slice(added.row(r));
        }
        let want = CholSolver::default().solve(&rotated, &v, 0.1).unwrap();
        for (a, b) in x.iter().zip(&want) {
            assert!((a - b).abs() < 1e-9);
        }
        // refresh recomputes the Gram from the rotated shards — still
        // the same answers.
        sess.refresh().unwrap();
        let x2 = sess.solve(&v).unwrap();
        for (a, b) in x2.iter().zip(&want) {
            assert!((a - b).abs() < 1e-9);
        }
    }

    #[test]
    fn backend_fault_is_typed_and_does_not_poison_session() {
        let mut rng = Rng::seed_from(436);
        let solver = ShardedCholSolver::new(2, 4);
        let s = Mat::randn(8, 32, &mut rng);
        let mut fact = solver.factor(&s, 0.1).unwrap();
        let v: Vec<f64> = (0..32).map(|_| rng.normal()).collect();
        fact.solve(&v).unwrap();
        solver.kill_worker(1);
        // The failure is the typed fatal Backend error — not BadInput,
        // not a panic, not a hang.
        match fact.solve(&v) {
            Err(SolveError::Backend { retryable, .. }) => assert!(!retryable),
            other => panic!("expected fatal Backend error, got {other:?}"),
        }
        // Session state survives: λ still reports the damped value and
        // a second call fails the same typed way instead of cascading.
        assert_eq!(fact.lambda(), 0.1);
        assert!(matches!(fact.solve(&v), Err(SolveError::Backend { .. })));
    }
}
