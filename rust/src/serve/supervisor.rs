//! Worker supervision and durable session records (PR 8).
//!
//! Three pieces, all transport-agnostic:
//!
//! * [`SessionRecord`] — the durable state needed to re-materialize a
//!   serving session after a worker dies: a score-window snapshot plus
//!   the rotation log since that snapshot. Recovery replays the log
//!   through the ordinary `update_rows` path, so a recovered factor is
//!   numerically identical to an unfailed run (the replayed rotations
//!   execute the same leader-side arithmetic in the same order). The
//!   record round-trips through the PR-4 [`Checkpoint`] container so it
//!   can be spilled to disk (`serve.record_dir`) or kept in memory.
//! * [`RetryPolicy`] — capped exponential backoff with *deterministic*
//!   jitter (no wall-clock entropy; tests pin exact sleep values).
//! * [`Supervisor`] — probes every worker of a [`ShardedCholSolver`]
//!   and respawns/reconnects the dead ones via the transport's
//!   `recover` hook, reporting what it found in a [`HealReport`].
//!   Revived workers come back with *empty* shard maps; the serving
//!   layer owns re-materializing affected sessions from their records.

use std::path::Path;
use std::time::Duration;

use crate::checkpoint::{Checkpoint, CheckpointError};
use crate::coordinator::ShardedCholSolver;
use crate::linalg::Mat;

/// One `update_rows` call, as recorded: which window rows were dropped
/// and what was appended. Replayed verbatim during recovery.
#[derive(Debug, Clone, PartialEq)]
pub struct RotationEntry {
    pub removed: Vec<usize>,
    pub added: Mat,
}

/// Durable record of a serving session: window snapshot + rotation log.
///
/// The log grows by one entry per rotation; every `snapshot_every`
/// entries the snapshot is refreshed from the live window and the log
/// cleared, bounding replay length R at recovery time. The recovery
/// cost model (EXPERIMENTS.md §Fault-tolerance) trades snapshot size
/// (n·m·8 bytes, re-serialized each refresh) against R replayed
/// rotations (O(k·n·m + k·n²) each).
#[derive(Debug, Clone, PartialEq)]
pub struct SessionRecord {
    snapshot: Mat,
    lambda: f64,
    log: Vec<RotationEntry>,
    snapshot_every: usize,
}

/// Apply one logged rotation leader-side, mirroring the semantics of
/// the distributed `update_rows` path (sorted removals, kept rows in
/// order, added rows appended). Entries were validated when first
/// applied, so any failure here means the record itself is corrupt.
fn apply_rotation(window: &Mat, removed: &[usize], added: &Mat) -> Result<Mat, CheckpointError> {
    let n = window.rows();
    let m = window.cols();
    let k_add = added.rows();
    if k_add > 0 && added.cols() != m {
        return Err(CheckpointError::Corrupt(format!(
            "rotation log: added rows have {} cols, window has {m}",
            added.cols()
        )));
    }
    let mut rem: Vec<usize> = removed.to_vec();
    rem.sort_unstable();
    let before = rem.len();
    rem.dedup();
    if rem.len() != before {
        return Err(CheckpointError::Corrupt("rotation log: duplicate removal index".into()));
    }
    if let Some(&bad) = rem.iter().find(|&&r| r >= n) {
        return Err(CheckpointError::Corrupt(format!(
            "rotation log: removal index {bad} out of range (window has {n} rows)"
        )));
    }
    let n_kept = n - rem.len();
    if n_kept + k_add == 0 {
        return Err(CheckpointError::Corrupt("rotation log: rotation empties the window".into()));
    }
    let mut keep = vec![true; n];
    for &r in &rem {
        keep[r] = false;
    }
    let mut out = Mat::zeros(n_kept + k_add, m);
    let mut dst = 0usize;
    for src in 0..n {
        if keep[src] {
            out.row_mut(dst).copy_from_slice(window.row(src));
            dst += 1;
        }
    }
    for r in 0..k_add {
        out.row_mut(n_kept + r).copy_from_slice(added.row(r));
    }
    Ok(out)
}

impl SessionRecord {
    /// Start a record from a freshly opened session's window.
    pub fn new(window: &Mat, lambda: f64, snapshot_every: usize) -> SessionRecord {
        SessionRecord {
            snapshot: window.clone(),
            lambda,
            log: Vec::new(),
            snapshot_every: snapshot_every.max(1),
        }
    }

    /// Track a λ change so recovery re-damps at the live value.
    pub fn set_lambda(&mut self, lambda: f64) {
        self.lambda = lambda;
    }

    pub fn lambda(&self) -> f64 {
        self.lambda
    }

    /// The window as of the last snapshot refresh.
    pub fn snapshot(&self) -> &Mat {
        &self.snapshot
    }

    /// Rotations applied since the snapshot, oldest first.
    pub fn log(&self) -> &[RotationEntry] {
        &self.log
    }

    /// Rotations a recovery would replay.
    pub fn replay_len(&self) -> usize {
        self.log.len()
    }

    /// Payload bytes held by the snapshot matrix.
    pub fn snapshot_bytes(&self) -> usize {
        self.snapshot.rows() * self.snapshot.cols() * std::mem::size_of::<f64>()
    }

    /// Log a successful rotation. `current` is the live window *after*
    /// the rotation; when the log reaches the snapshot cadence the
    /// snapshot is refreshed from it and the log cleared. Returns true
    /// iff a snapshot refresh happened (callers count these).
    pub fn record_rotation(&mut self, removed: &[usize], added: &Mat, current: &Mat) -> bool {
        self.log.push(RotationEntry { removed: removed.to_vec(), added: added.clone() });
        if self.log.len() >= self.snapshot_every {
            self.snapshot = current.clone();
            self.log.clear();
            true
        } else {
            false
        }
    }

    /// Reconstruct the live window leader-side: snapshot + full log.
    /// Used by the cold-refactor and local-fallback recovery paths
    /// (the replay path instead feeds the log through `update_rows`).
    pub fn materialize_window(&self) -> Result<Mat, CheckpointError> {
        let mut w = self.snapshot.clone();
        for e in &self.log {
            w = apply_rotation(&w, &e.removed, &e.added)?;
        }
        Ok(w)
    }

    /// Encode into the PR-4 checkpoint container. Tensors: `meta` =
    /// `[lambda, snapshot_every, log_len]`, `snapshot` (shape-headed
    /// matrix), and per entry `log.{i}.removed` / `log.{i}.added`.
    pub fn to_checkpoint(&self) -> Checkpoint {
        let mut ck = Checkpoint::new();
        ck.insert(
            "meta",
            vec![self.lambda, self.snapshot_every as f64, self.log.len() as f64],
        );
        ck.insert_mat("snapshot", &self.snapshot);
        for (i, e) in self.log.iter().enumerate() {
            ck.insert(
                &format!("log.{i}.removed"),
                e.removed.iter().map(|&r| r as f64).collect(),
            );
            ck.insert_mat(&format!("log.{i}.added"), &e.added);
        }
        ck
    }

    /// Decode a record written by [`SessionRecord::to_checkpoint`],
    /// validating every field it trusts.
    pub fn from_checkpoint(ck: &Checkpoint) -> Result<SessionRecord, CheckpointError> {
        let meta = ck
            .get("meta")
            .ok_or_else(|| CheckpointError::Corrupt("session record: missing meta".into()))?;
        if meta.len() != 3 {
            return Err(CheckpointError::Corrupt(format!(
                "session record: meta has {} values, want 3",
                meta.len()
            )));
        }
        let lambda = meta[0];
        let usize_field = |v: f64, what: &str| -> Result<usize, CheckpointError> {
            if v < 0.0 || v.fract() != 0.0 {
                return Err(CheckpointError::Corrupt(format!(
                    "session record: non-integral {what} ({v})"
                )));
            }
            Ok(v as usize)
        };
        let snapshot_every = usize_field(meta[1], "snapshot cadence")?;
        if snapshot_every == 0 {
            return Err(CheckpointError::Corrupt("session record: zero snapshot cadence".into()));
        }
        let n_log = usize_field(meta[2], "log length")?;
        let snapshot = ck.get_mat("snapshot")?;
        let mut log = Vec::with_capacity(n_log);
        for i in 0..n_log {
            let name = format!("log.{i}.removed");
            let raw = ck.get(&name).ok_or_else(|| {
                CheckpointError::Corrupt(format!("session record: missing tensor {name:?}"))
            })?;
            let mut removed = Vec::with_capacity(raw.len());
            for &v in raw {
                removed.push(usize_field(v, "removal index")?);
            }
            let added = ck.get_mat(&format!("log.{i}.added"))?;
            log.push(RotationEntry { removed, added });
        }
        Ok(SessionRecord { snapshot, lambda, log, snapshot_every })
    }

    /// Persist atomically (tmp + rename, via the checkpoint layer).
    pub fn save(&self, path: &Path) -> Result<(), CheckpointError> {
        self.to_checkpoint().save(path)
    }

    pub fn load(path: &Path) -> Result<SessionRecord, CheckpointError> {
        SessionRecord::from_checkpoint(&Checkpoint::load(path)?)
    }
}

/// SplitMix64 finalizer: cheap, well-mixed, and fully deterministic —
/// the jitter source for backoff (tests pin exact values).
fn splitmix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9e3779b97f4a7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
    z ^ (z >> 31)
}

/// Capped exponential backoff with deterministic jitter.
///
/// Attempt `a` sleeps a value in `[exp/2, exp]` where
/// `exp = min(cap_ms, base_ms · 2^a)` — the classic "equal jitter"
/// scheme, except the jitter is a hash of `(attempt, salt)` rather
/// than wall-clock randomness, so retry schedules are reproducible
/// under a fixed salt (the serving layer salts by request id).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RetryPolicy {
    pub base_ms: u64,
    pub cap_ms: u64,
    pub max_retries: u32,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy { base_ms: 10, cap_ms: 1_000, max_retries: 4 }
    }
}

impl RetryPolicy {
    pub fn new(base_ms: u64, cap_ms: u64, max_retries: u32) -> RetryPolicy {
        RetryPolicy { base_ms, cap_ms: cap_ms.max(base_ms), max_retries }
    }

    /// Backoff for the given (0-based) attempt, jittered by `salt`.
    pub fn backoff_ms(&self, attempt: u32, salt: u64) -> u64 {
        let exp = self
            .base_ms
            .saturating_mul(1u64.checked_shl(attempt.min(32)).unwrap_or(u64::MAX))
            .min(self.cap_ms);
        let lo = exp / 2;
        let span = exp - lo + 1;
        lo + splitmix64(salt ^ (u64::from(attempt) << 48)) % span
    }
}

/// What a [`Supervisor::heal`] sweep found and did.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct HealReport {
    /// Workers probed (= the solver's worker count).
    pub probed: usize,
    /// Workers that failed the health probe.
    pub dead: Vec<usize>,
    /// Dead workers successfully revived (respawned or reconnected)
    /// and re-probed healthy.
    pub respawned: usize,
    /// Dead workers that could not be revived.
    pub failed: Vec<usize>,
}

impl HealReport {
    /// True iff every worker is (now) healthy.
    pub fn healthy(&self) -> bool {
        self.failed.is_empty()
    }
}

/// Health-checks a sharded solver's workers and revives the dead ones.
///
/// Channel-backed workers are respawned as fresh threads; socket-backed
/// workers get a fresh socket pair + worker thread. Either way the
/// revived worker's shard map is empty — callers must re-materialize
/// sessions (see [`SessionRecord`]) before routing work at it.
#[derive(Debug, Clone, Copy)]
pub struct Supervisor {
    probe_timeout: Duration,
}

impl Default for Supervisor {
    fn default() -> Self {
        Supervisor { probe_timeout: Duration::from_millis(500) }
    }
}

impl Supervisor {
    /// `probe_timeout` bounds how long one health probe may wait for a
    /// Ping reply. Dead workers fail fast (their reply channel is
    /// dropped); the timeout only matters for stalled-but-alive ones,
    /// so keep it generous to avoid respawning a merely busy worker.
    pub fn new(probe_timeout: Duration) -> Supervisor {
        Supervisor { probe_timeout }
    }

    /// Probe every worker; revive the ones that fail. Returns what
    /// happened — callers decide how to re-materialize sessions.
    pub fn heal(&self, solver: &ShardedCholSolver) -> HealReport {
        let mut report = HealReport { probed: solver.workers(), ..HealReport::default() };
        for w in 0..solver.workers() {
            if solver.probe_worker(w, self.probe_timeout) {
                continue;
            }
            report.dead.push(w);
            let revived = solver.recover_worker(w).is_ok()
                && solver.probe_worker(w, self.probe_timeout);
            if revived {
                report.respawned += 1;
            } else {
                report.failed.push(w);
            }
        }
        report
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;

    fn test_window(n: usize, m: usize, seed: u64) -> Mat {
        let mut rng = Rng::seed_from(seed);
        Mat::randn(n, m, &mut rng)
    }

    #[test]
    fn record_roundtrips_through_checkpoint_bytes() {
        let w = test_window(6, 5, 11);
        let mut rng = Rng::seed_from(12);
        let mut rec = SessionRecord::new(&w, 0.25, 16);
        rec.record_rotation(&[0, 3], &Mat::randn(2, 5, &mut rng), &w);
        rec.record_rotation(&[1], &Mat::randn(1, 5, &mut rng), &w);
        rec.set_lambda(0.5);
        let bytes = rec.to_checkpoint().to_bytes();
        let back =
            SessionRecord::from_checkpoint(&Checkpoint::from_bytes(&bytes).unwrap()).unwrap();
        assert_eq!(back, rec);
        assert_eq!(back.replay_len(), 2);
        assert_eq!(back.lambda().to_bits(), 0.5f64.to_bits());
        for (a, b) in back.snapshot().as_slice().iter().zip(rec.snapshot().as_slice()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn snapshot_cadence_refreshes_and_clears_log() {
        let w0 = test_window(4, 3, 21);
        let mut rec = SessionRecord::new(&w0, 1e-3, 2);
        let add = test_window(1, 3, 22);
        let w1 = apply_rotation(&w0, &[0], &add).unwrap();
        assert!(!rec.record_rotation(&[0], &add, &w1), "first rotation below cadence");
        assert_eq!(rec.replay_len(), 1);
        let w2 = apply_rotation(&w1, &[1], &add).unwrap();
        assert!(rec.record_rotation(&[1], &add, &w2), "cadence hit refreshes snapshot");
        assert_eq!(rec.replay_len(), 0, "log cleared at refresh");
        for (a, b) in rec.snapshot().as_slice().iter().zip(w2.as_slice()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(rec.snapshot_bytes(), 4 * 3 * 8);
    }

    #[test]
    fn materialized_window_matches_directly_rotated() {
        let w0 = test_window(8, 4, 31);
        let mut rng = Rng::seed_from(32);
        let mut rec = SessionRecord::new(&w0, 1e-2, 64);
        let mut live = w0.clone();
        for (k, rem) in [vec![2usize, 5], vec![0], vec![3, 1]].into_iter().enumerate() {
            let add = Mat::randn(k + 1, 4, &mut rng);
            live = apply_rotation(&live, &rem, &add).unwrap();
            rec.record_rotation(&rem, &add, &live);
        }
        let got = rec.materialize_window().unwrap();
        assert_eq!(got.shape(), live.shape());
        for (a, b) in got.as_slice().iter().zip(live.as_slice()) {
            assert_eq!(a.to_bits(), b.to_bits(), "replay must be bit-identical");
        }
    }

    #[test]
    fn corrupt_log_is_typed_not_a_panic() {
        let w = test_window(3, 2, 41);
        let oob = SessionRecord {
            snapshot: w.clone(),
            lambda: 0.1,
            log: vec![RotationEntry { removed: vec![7], added: Mat::zeros(0, 2) }],
            snapshot_every: 4,
        };
        assert!(matches!(oob.materialize_window(), Err(CheckpointError::Corrupt(_))));
        let dup = SessionRecord {
            snapshot: w,
            lambda: 0.1,
            log: vec![RotationEntry { removed: vec![1, 1], added: Mat::zeros(0, 2) }],
            snapshot_every: 4,
        };
        assert!(matches!(dup.materialize_window(), Err(CheckpointError::Corrupt(_))));
        let mut ck = Checkpoint::new();
        ck.insert("meta", vec![0.1, 4.0, 1.0]); // claims one log entry, has none
        ck.insert_mat("snapshot", &Mat::zeros(2, 2));
        assert!(matches!(
            SessionRecord::from_checkpoint(&ck),
            Err(CheckpointError::Corrupt(_))
        ));
    }

    #[test]
    fn backoff_is_bounded_and_deterministic() {
        let p = RetryPolicy::new(10, 1_000, 6);
        for attempt in 0..8u32 {
            let exp = 10u64.saturating_mul(1 << attempt.min(32)).min(1_000);
            let b = p.backoff_ms(attempt, 99);
            assert!(b >= exp / 2 && b <= exp, "attempt {attempt}: {b} outside [{}, {exp}]", exp / 2);
            assert_eq!(b, p.backoff_ms(attempt, 99), "same salt, same sleep");
        }
        // Jitter actually jitters: different salts disagree somewhere.
        let spread: Vec<u64> = (0..16).map(|s| p.backoff_ms(5, s)).collect();
        assert!(spread.iter().any(|&b| b != spread[0]), "jitter collapsed: {spread:?}");
        // Attempt count saturates rather than overflowing.
        assert!(p.backoff_ms(63, 0) <= 1_000);
    }

    #[test]
    fn heal_revives_a_killed_channel_worker() {
        let solver = ShardedCholSolver::new(2, 4);
        let sup = Supervisor::default();
        let all_up = sup.heal(&solver);
        assert_eq!(all_up, HealReport { probed: 2, ..HealReport::default() });
        solver.kill_worker(0);
        let report = sup.heal(&solver);
        assert_eq!(report.probed, 2);
        assert_eq!(report.dead, vec![0]);
        assert_eq!(report.respawned, 1);
        assert!(report.healthy(), "recovery must leave no failed workers: {report:?}");
        assert!(solver.probe_worker(0, Duration::from_millis(500)));
        solver.shutdown();
    }
}
