//! Multi-tenant damped-solve server (PR 7 tentpole).
//!
//! One [`Server`] multiplexes many tenant [`Client`]s onto a single
//! sharded Algorithm-1 backend. Tenants open sessions (submit a score
//! matrix, get a cached λ-independent staging), then stream single-RHS
//! solves and window rotations; a dispatcher thread drains the bounded
//! request queue once per `tick_ms` and **coalesces** solves sharing
//! `(session, λ)` into one `solve_many` panel — the PR-2/PR-5
//! amortization applied *across* tenants. Admission never OOMs and never
//! queues unboundedly:
//!
//! | pressure point            | policy                                       |
//! |---------------------------|----------------------------------------------|
//! | connection slots          | `serve.tenants` cap → [`ServeError::TenantLimit`] |
//! | dispatch queue            | `serve.queue_depth` cap → [`ServeError::Overloaded`] + retry-after |
//! | session memory            | `cost.rs` model vs `serve.budget_gb` → [`ServeError::OverBudget`] |
//!
//! Everything below the dispatcher is the PR-2 session API over the
//! pluggable [`super::transport::ShardTransport`], so the same server
//! runs against in-process channel workers or out-of-process
//! Unix-socket shard workers, bit-identically.
//!
//! Fault tolerance (PR 8): every request carries a deadline
//! (`serve.deadline_ms`); transient backend faults are retried with
//! capped, deterministically-jittered backoff (`serve.max_retries`);
//! fatal transport faults trigger the [`super::supervisor::Supervisor`]
//! (probe + respawn dead workers) followed by session
//! re-materialization from the durable [`SessionRecord`] — snapshot +
//! rotation-log replay through the ordinary `update_rows` path, so the
//! recovered factor matches an unfailed run. When recovery itself fails
//! or blows the deadline, the dispatcher degrades to a leader-local
//! Cholesky of the recorded window (`ServeStats::local_fallbacks`)
//! rather than dropping the request.

use super::queue::{
    coalesce_solves, Pending, RequestQueue, RotateItem, ServeError, SolveGroup, SolveItem,
};
use super::supervisor::{RetryPolicy, SessionRecord, Supervisor};
use super::transport::{ChannelTransport, ShardTransport, TransportKind};
use crate::config::Config;
use crate::coordinator::{ShardedCholSolver, ShardedWindowSession};
use crate::linalg::{KernelConfig, Mat};
use crate::solver::{memory_bytes, CholSolver, Factorization, MemoryBudget, SolveError, SolverKind};
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

/// Serving-layer tunables (`serve.*` config keys plus the backend
/// topology inherited from `coordinator.*` / `solver.*`).
#[derive(Debug, Clone, PartialEq)]
pub struct ServeOptions {
    /// Concurrent tenant connection slots (`serve.tenants`).
    pub tenants: usize,
    /// Dispatch-queue depth shared by all tenants
    /// (`serve.queue_depth`); must be ≥ `tenants` so every connected
    /// tenant can keep at least one request in flight.
    pub queue_depth: usize,
    /// Gathering window per dispatch tick in ms (`serve.tick_ms`).
    /// Larger ticks coalesce more RHS per panel at higher p50; 0
    /// dispatches immediately (the serial baseline for the bench).
    pub tick_ms: u64,
    /// Session-memory budget in GB under the `cost.rs` model
    /// (`serve.budget_gb`; 0 = the paper's 80 GB A100).
    pub budget_gb: f64,
    /// Shard worker transport (`serve.transport = "channels"|"socket"`).
    pub transport: TransportKind,
    /// Shard worker count (`coordinator.workers`).
    pub workers: usize,
    /// Per-worker mailbox depth for the channel transport
    /// (`coordinator.queue_depth`).
    pub worker_queue_depth: usize,
    /// Kernel configuration for the dense stages (`solver.threads` /
    /// `solver.isa`).
    pub kernel: KernelConfig,
    /// Cross-tenant RHS coalescing. On by default; the serving bench
    /// turns it off to measure the serial per-request baseline.
    pub coalesce: bool,
    /// Per-request deadline in ms (`serve.deadline_ms`): the budget for
    /// queueing + dispatch + any retries/recovery before a request gets
    /// a typed [`ServeError::DeadlineExceeded`] instead of an answer.
    pub deadline_ms: u64,
    /// Cap on transient-fault retries per dispatched request
    /// (`serve.max_retries`); each retry sleeps a capped-exponential,
    /// deterministically-jittered backoff.
    pub max_retries: u32,
    /// Session-record snapshot cadence (`serve.snapshot_every`): refresh
    /// the window snapshot and clear the rotation log every this many
    /// rotations, bounding recovery replay length.
    pub snapshot_every: usize,
    /// Worker supervision (`serve.supervise`). Off restores the PR-7
    /// behavior: fatal transport faults propagate as typed errors.
    pub supervise: bool,
    /// Directory for durable session records (`serve.record_dir`);
    /// empty keeps records in memory only.
    pub record_dir: String,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            tenants: 16,
            queue_depth: 64,
            tick_ms: 2,
            budget_gb: 0.0,
            transport: TransportKind::Channels,
            workers: 4,
            worker_queue_depth: 4,
            kernel: KernelConfig::serial(),
            coalesce: true,
            deadline_ms: 5_000,
            max_retries: 4,
            snapshot_every: 16,
            supervise: true,
            record_dir: String::new(),
        }
    }
}

impl ServeOptions {
    /// Build serving options from a validated [`Config`] (the
    /// `dngd serve` path): `serve.*` for the front-end, `coordinator.*`
    /// for the shard topology, `solver.*` for the kernels.
    pub fn from_config(cfg: &Config) -> Result<ServeOptions, String> {
        let opts = ServeOptions {
            tenants: cfg.serve.tenants,
            queue_depth: cfg.serve.queue_depth,
            tick_ms: cfg.serve.tick_ms,
            budget_gb: cfg.serve.budget_gb,
            transport: TransportKind::parse(&cfg.serve.transport)?,
            workers: cfg.coordinator.workers,
            worker_queue_depth: cfg.coordinator.queue_depth,
            kernel: cfg.solver.options().kernel(),
            coalesce: true,
            deadline_ms: cfg.serve.deadline_ms,
            max_retries: cfg.serve.max_retries,
            snapshot_every: cfg.serve.snapshot_every,
            supervise: cfg.serve.supervise,
            record_dir: cfg.serve.record_dir.clone(),
        };
        opts.validate()?;
        Ok(opts)
    }

    /// Range + cross-field checks, shared by the TOML/`--set` path
    /// (via [`Config::validate`]) and direct [`Server::start`] callers.
    pub fn validate(&self) -> Result<(), String> {
        if self.tenants == 0 {
            return Err("serve.tenants must be ≥ 1".into());
        }
        if self.queue_depth < self.tenants {
            return Err(format!(
                "serve.queue_depth ({}) must be ≥ serve.tenants ({}): every connected tenant \
                 needs at least one queue slot or admission livelocks",
                self.queue_depth, self.tenants
            ));
        }
        if self.tick_ms > 10_000 {
            return Err("serve.tick_ms must be ≤ 10000 (a tick is a gathering window, not a schedule)".into());
        }
        if !self.budget_gb.is_finite() || self.budget_gb < 0.0 {
            return Err("serve.budget_gb must be ≥ 0 (0 = the 80 GB A100 default)".into());
        }
        if self.workers == 0 {
            return Err("coordinator.workers must be ≥ 1".into());
        }
        if self.worker_queue_depth == 0 {
            return Err("coordinator.queue_depth must be ≥ 1".into());
        }
        if self.deadline_ms == 0 || self.deadline_ms > 600_000 {
            return Err("serve.deadline_ms must be in 1..=600000".into());
        }
        if self.snapshot_every == 0 {
            return Err("serve.snapshot_every must be ≥ 1".into());
        }
        Ok(())
    }

    /// The modeled budget gating session admission.
    fn budget(&self) -> MemoryBudget {
        if self.budget_gb > 0.0 {
            MemoryBudget::bytes_for_test((self.budget_gb * 1e9) as u64)
        } else {
            MemoryBudget::a100_80gb()
        }
    }
}

/// Counters reported by [`Server::stats`] / [`Server::shutdown`].
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ServeStats {
    /// Solve requests admitted to the queue.
    pub submitted: u64,
    /// Solve requests answered successfully.
    pub completed: u64,
    /// Requests rejected at admission (queue full / shutting down).
    pub rejected: u64,
    /// Window rotations applied.
    pub rotations: u64,
    /// `solve_many` panels dispatched to the backend.
    pub panels: u64,
    /// RHS rows that rode along in an already-dispatched panel — the
    /// direct measure of cross-tenant coalescing (0 when off).
    pub coalesced_rows: u64,
    /// Largest panel dispatched.
    pub max_panel_rows: usize,
    /// Requests that aged past their deadline (queued or mid-recovery)
    /// and were answered with [`ServeError::DeadlineExceeded`].
    pub deadline_exceeded: u64,
    /// Transient backend faults absorbed by the dispatcher's backoff
    /// loop (each one slept and resubmitted the same panel).
    pub backend_retries: u64,
    /// Dead shard workers revived by the supervisor (respawned threads
    /// or reconnected sockets).
    pub worker_respawns: u64,
    /// Sessions re-materialized via the replay path: snapshot staging +
    /// rotation-log replay through `update_rows`.
    pub session_replays: u64,
    /// Sessions re-materialized via the cold path: refactor of the
    /// fully-materialized window (replay itself failed).
    pub session_refactors: u64,
    /// Requests answered by the degraded leader-local Cholesky because
    /// distributed recovery failed or blew the deadline.
    pub local_fallbacks: u64,
    /// Session-record snapshot refreshes (rotation log hit
    /// `serve.snapshot_every`).
    pub snapshots: u64,
    /// Per-worker processed-job counters, available only from
    /// [`Server::shutdown`] once every client and session is gone.
    pub worker_jobs: Vec<u64>,
}

struct TenantSession {
    fact: ShardedWindowSession,
    /// `cost.rs` admission charge, released on close.
    bytes: u64,
    /// Durable recovery record: window snapshot + rotation log.
    record: SessionRecord,
}

struct BudgetState {
    admitted: u64,
    limit: u64,
}

struct Inner {
    opts: ServeOptions,
    solver: Arc<ShardedCholSolver>,
    sessions: Mutex<HashMap<u64, TenantSession>>,
    next_session: AtomicU64,
    queue: RequestQueue,
    budget: Mutex<BudgetState>,
    tenants: AtomicUsize,
    stats: Mutex<ServeStats>,
    supervisor: Supervisor,
    retry: RetryPolicy,
    /// `serve.record_dir` parsed once; `None` = in-memory records only.
    record_dir: Option<PathBuf>,
}

impl Inner {
    fn persist_record(&self, sid: u64, record: &SessionRecord) {
        if let Some(dir) = &self.record_dir {
            // Best-effort spill: a failed write degrades durability (a
            // leader restart loses the session), never availability.
            let _ = record.save(&dir.join(format!("session-{sid}.ckpt")));
        }
    }
}

/// The serving front-end. [`Server::start`] spawns the shard workers
/// and the dispatcher thread; [`Server::client`] hands out tenant
/// connections; [`Server::shutdown`] drains in-flight work and returns
/// the final counters (including the per-worker job counts from the
/// transport's drained shutdown).
pub struct Server {
    inner: Arc<Inner>,
    dispatcher: Option<thread::JoinHandle<()>>,
}

/// One tenant connection. Holds a `serve.tenants` slot until dropped.
pub struct Client {
    inner: Arc<Inner>,
}

/// Handle to an in-flight async solve; [`SolveTicket::wait`] blocks for
/// the dispatched answer.
pub struct SolveTicket {
    rx: Receiver<Result<Vec<f64>, ServeError>>,
}

impl SolveTicket {
    pub fn wait(self) -> Result<Vec<f64>, ServeError> {
        self.rx.recv().unwrap_or(Err(ServeError::ShuttingDown))
    }
}

#[cfg(unix)]
fn socket_transport(
    workers: usize,
    kernel: KernelConfig,
) -> Result<Box<dyn ShardTransport>, String> {
    let t = super::transport::SocketTransport::spawn(workers, kernel)
        .map_err(|e| format!("socket transport: {e}"))?;
    Ok(Box::new(t))
}

#[cfg(not(unix))]
fn socket_transport(
    _workers: usize,
    _kernel: KernelConfig,
) -> Result<Box<dyn ShardTransport>, String> {
    Err("serve.transport = \"socket\" requires a Unix platform (use \"channels\")".into())
}

impl Server {
    /// Spawn the shard workers (over the configured transport) and the
    /// dispatcher thread.
    pub fn start(opts: ServeOptions) -> Result<Server, String> {
        opts.validate()?;
        let transport: Box<dyn ShardTransport> = match opts.transport {
            TransportKind::Channels => Box::new(ChannelTransport::spawn(
                opts.workers,
                opts.worker_queue_depth,
                opts.kernel,
            )),
            TransportKind::Socket => socket_transport(opts.workers, opts.kernel)?,
        };
        let solver = Arc::new(ShardedCholSolver::with_transport(transport, opts.kernel));
        let limit = opts.budget().bytes();
        // Retry-after hint ≈ one gathering tick (min 1 ms).
        let retry_after_ms = opts.tick_ms.max(1);
        let record_dir =
            if opts.record_dir.is_empty() { None } else { Some(PathBuf::from(&opts.record_dir)) };
        let inner = Arc::new(Inner {
            queue: RequestQueue::new(opts.queue_depth, retry_after_ms),
            retry: RetryPolicy { max_retries: opts.max_retries, ..RetryPolicy::default() },
            supervisor: Supervisor::default(),
            record_dir,
            opts,
            solver,
            sessions: Mutex::new(HashMap::new()),
            next_session: AtomicU64::new(0),
            budget: Mutex::new(BudgetState { admitted: 0, limit }),
            tenants: AtomicUsize::new(0),
            stats: Mutex::new(ServeStats::default()),
        });
        let inner2 = inner.clone();
        let dispatcher = thread::Builder::new()
            .name("dngd-serve-dispatcher".into())
            .spawn(move || dispatcher_loop(&inner2))
            .map_err(|e| format!("spawn dispatcher: {e}"))?;
        Ok(Server { inner, dispatcher: Some(dispatcher) })
    }

    /// Connect a tenant, or reject with [`ServeError::TenantLimit`]
    /// when all slots are taken (retryable: slots free when clients
    /// drop).
    pub fn client(&self) -> Result<Client, ServeError> {
        let prev = self.inner.tenants.fetch_add(1, Ordering::SeqCst);
        if prev >= self.inner.opts.tenants {
            self.inner.tenants.fetch_sub(1, Ordering::SeqCst);
            return Err(ServeError::TenantLimit { tenants: self.inner.opts.tenants });
        }
        Ok(Client { inner: self.inner.clone() })
    }

    /// Snapshot of the live counters (worker_jobs stays empty until
    /// shutdown).
    pub fn stats(&self) -> ServeStats {
        self.inner.stats.lock().unwrap().clone()
    }

    /// Which transport backs this server (`"channels"` / `"socket"`).
    pub fn transport_name(&self) -> &'static str {
        self.inner.solver.transport_name()
    }

    /// Fault injection: kill shard worker `w` (blocks until the death
    /// is observable). Used by `dngd chaos` and the soak tests.
    pub fn inject_kill(&self, w: usize) {
        self.inner.solver.kill_worker(w);
    }

    /// Fault injection: stall shard worker `w` for `ms` milliseconds
    /// (fire-and-forget; the worker stays healthy, just slow).
    pub fn inject_stall(&self, w: usize, ms: u64) {
        self.inner.solver.stall_worker(w, ms);
    }

    /// Fault injection: write a garbage length prefix at worker `w`'s
    /// framing layer. Returns false when the transport has no frames to
    /// corrupt (channels).
    pub fn inject_corrupt_frame(&self, w: usize) -> bool {
        self.inner.solver.inject_corrupt_frame(w)
    }

    /// Live session count — the chaos harness' session-leak check.
    pub fn live_sessions(&self) -> usize {
        self.inner.sessions.lock().unwrap().len()
    }

    /// Bytes currently charged against the admission budget — the
    /// chaos harness' budget-leak check (0 once every session closed).
    pub fn admitted_bytes(&self) -> u64 {
        self.inner.budget.lock().unwrap().admitted
    }

    /// Stop admission, drain the queue, join the dispatcher, and — if
    /// no client or session handle is still alive — drop all sessions
    /// and shut the backend down, harvesting the per-worker job
    /// counters into the returned stats.
    pub fn shutdown(mut self) -> ServeStats {
        self.inner.queue.stop();
        if let Some(d) = self.dispatcher.take() {
            let _ = d.join();
        }
        let inner = self.inner.clone();
        drop(self); // release the Server's Arc (Drop sees dispatcher=None)
        let mut stats = inner.stats.lock().unwrap().clone();
        if let Ok(inner) = Arc::try_unwrap(inner) {
            // Sessions drop first (each frees its worker shards over the
            // still-live transport), then the backend drains + joins.
            drop(inner.sessions.into_inner().unwrap());
            if let Ok(solver) = Arc::try_unwrap(inner.solver) {
                stats.worker_jobs = solver.shutdown();
            }
        }
        stats
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        // Dropped without shutdown(): stop admission and join the
        // dispatcher so no thread outlives the handle. The backend pool
        // drains via the transport's own Drop once the last
        // client/session releases `Inner`.
        self.inner.queue.stop();
        if let Some(d) = self.dispatcher.take() {
            let _ = d.join();
        }
    }
}

fn check_serve_lambda(lambda: f64) -> Result<(), ServeError> {
    if lambda <= 0.0 || !lambda.is_finite() {
        return Err(ServeError::Solver(SolveError::BadInput(format!(
            "damping must be positive and finite, got λ = {lambda}"
        ))));
    }
    Ok(())
}

impl Client {
    /// Submit a score matrix and stage a session at `lambda`. Admission
    /// is charged up front under the `cost.rs` memory model; rejected
    /// sessions cost nothing.
    pub fn open_session(&self, scores: Mat, lambda: f64) -> Result<u64, ServeError> {
        check_serve_lambda(lambda)?;
        let (n, m) = (scores.rows(), scores.cols());
        if n == 0 || m == 0 {
            return Err(ServeError::Solver(SolveError::BadInput(
                "open_session: empty score matrix".into(),
            )));
        }
        let bytes = memory_bytes(SolverKind::Chol, n, m);
        {
            let mut b = self.inner.budget.lock().unwrap();
            let free = b.limit.saturating_sub(b.admitted);
            if bytes > free {
                return Err(ServeError::OverBudget {
                    required_bytes: bytes,
                    budget_bytes: free,
                    retry_after_ms: self.inner.opts.tick_ms.max(1),
                });
            }
            b.admitted += bytes;
        }
        // The durable record is cut before the scores move backend-ward,
        // so recovery never depends on distributed state.
        let record = SessionRecord::new(&scores, lambda, self.inner.opts.snapshot_every);
        // Cold staging runs on the tenant thread (the transport demuxes
        // concurrent requests), so a slow admit never stalls dispatch.
        let mut fact = ShardedCholSolver::window_session(&self.inner.solver, scores);
        if let Err(e) = fact.redamp(lambda) {
            // A dead worker at admission is recoverable: heal the pool
            // and restage once from the record's snapshot.
            let fatal = matches!(e, SolveError::Backend { retryable: false, .. });
            if !(fatal && self.inner.opts.supervise) {
                self.inner.budget.lock().unwrap().admitted -= bytes;
                return Err(e.into());
            }
            let report = self.inner.supervisor.heal(&self.inner.solver);
            self.inner.stats.lock().unwrap().worker_respawns += report.respawned as u64;
            drop(fact);
            fact =
                ShardedCholSolver::window_session(&self.inner.solver, record.snapshot().clone());
            if let Err(e2) = fact.redamp(lambda) {
                self.inner.budget.lock().unwrap().admitted -= bytes;
                return Err(e2.into());
            }
        }
        let sid = self.inner.next_session.fetch_add(1, Ordering::Relaxed) + 1;
        self.inner.persist_record(sid, &record);
        self.inner.sessions.lock().unwrap().insert(sid, TenantSession { fact, bytes, record });
        Ok(sid)
    }

    /// Attach to an existing session (multi-tenant sharing of one
    /// cached staging); errors if it was never opened or was closed.
    pub fn attach(&self, sid: u64) -> Result<(), ServeError> {
        if self.inner.sessions.lock().unwrap().contains_key(&sid) {
            Ok(())
        } else {
            Err(ServeError::UnknownSession(sid))
        }
    }

    /// Close a session, releasing its worker shards and its admission
    /// charge.
    pub fn close_session(&self, sid: u64) -> Result<(), ServeError> {
        let sess = self
            .inner
            .sessions
            .lock()
            .unwrap()
            .remove(&sid)
            .ok_or(ServeError::UnknownSession(sid))?;
        self.inner.budget.lock().unwrap().admitted -= sess.bytes;
        if let Some(dir) = &self.inner.record_dir {
            let _ = std::fs::remove_file(dir.join(format!("session-{sid}.ckpt")));
        }
        drop(sess); // frees the worker shards (blocking DropShard fan-out)
        Ok(())
    }

    /// Enqueue one RHS against session `sid` at damping `lambda`;
    /// returns a ticket immediately (the dispatcher answers after the
    /// next tick, possibly coalesced with other tenants' RHS).
    pub fn solve_async(
        &self,
        sid: u64,
        lambda: f64,
        rhs: &[f64],
    ) -> Result<SolveTicket, ServeError> {
        check_serve_lambda(lambda)?;
        let m = {
            let sessions = self.inner.sessions.lock().unwrap();
            sessions.get(&sid).ok_or(ServeError::UnknownSession(sid))?.fact.dim()
        };
        if rhs.len() != m {
            return Err(ServeError::Solver(SolveError::BadInput(format!(
                "solve: rhs has {} entries but session {sid} solves m = {m}",
                rhs.len()
            ))));
        }
        let (tx, rx) = channel();
        let now = Instant::now();
        let item = Pending::Solve(SolveItem {
            sid,
            lambda,
            rhs: rhs.to_vec(),
            reply: tx,
            enqueued: now,
            deadline: now + Duration::from_millis(self.inner.opts.deadline_ms),
        });
        match self.inner.queue.try_push(item) {
            Ok(()) => {
                self.inner.stats.lock().unwrap().submitted += 1;
                Ok(SolveTicket { rx })
            }
            Err(e) => {
                self.inner.stats.lock().unwrap().rejected += 1;
                Err(e)
            }
        }
    }

    /// Blocking solve: [`Client::solve_async`] + wait, resubmitting on
    /// retryable rejections (admission back-pressure, transient backend
    /// faults). Sleeps the server's retry-after hint when one is given,
    /// else the capped-exponential backoff, until the per-request
    /// deadline — then reports [`ServeError::DeadlineExceeded`] with
    /// how long it tried and how many resubmits it burned.
    pub fn solve(&self, sid: u64, lambda: f64, rhs: &[f64]) -> Result<Vec<f64>, ServeError> {
        let start = Instant::now();
        let deadline = start + Duration::from_millis(self.inner.opts.deadline_ms);
        let mut retries: u64 = 0;
        loop {
            let err = match self.solve_async(sid, lambda, rhs) {
                Ok(ticket) => match ticket.wait() {
                    Ok(x) => return Ok(x),
                    Err(e) => e,
                },
                Err(e) => e,
            };
            if !err.is_retryable() {
                return Err(err);
            }
            let backoff = Duration::from_millis(
                err.retry_after_ms()
                    .unwrap_or(0)
                    .max(self.inner.retry.backoff_ms(retries.min(63) as u32, sid)),
            );
            if Instant::now() + backoff >= deadline {
                return Err(ServeError::DeadlineExceeded {
                    elapsed_ms: start.elapsed().as_millis() as u64,
                    retries,
                });
            }
            thread::sleep(backoff);
            retries += 1;
        }
    }

    /// Rotate rows of the session's sliding window (the PR-5 streaming
    /// `update_rows`), serialized through the dispatch queue so a
    /// tick's solves always see a consistent window. Blocks for the
    /// result. Only *admission* rejections are resubmitted (hint-aware,
    /// deadline-bounded): once dispatched, a rotation may have mutated
    /// the window, so its outcome is reported as-is.
    pub fn rotate(&self, sid: u64, removed: &[usize], added: Mat) -> Result<(), ServeError> {
        if !self.inner.sessions.lock().unwrap().contains_key(&sid) {
            return Err(ServeError::UnknownSession(sid));
        }
        let start = Instant::now();
        let deadline = start + Duration::from_millis(self.inner.opts.deadline_ms);
        let mut retries: u64 = 0;
        loop {
            let (tx, rx) = channel();
            let item = Pending::Rotate(RotateItem {
                sid,
                removed: removed.to_vec(),
                added: added.clone(),
                reply: tx,
                enqueued: Instant::now(),
                deadline,
            });
            let err = match self.inner.queue.try_push(item) {
                Ok(()) => return rx.recv().unwrap_or(Err(ServeError::ShuttingDown)),
                Err(e) => {
                    self.inner.stats.lock().unwrap().rejected += 1;
                    e
                }
            };
            if !err.is_retryable() {
                return Err(err);
            }
            let backoff = Duration::from_millis(
                err.retry_after_ms()
                    .unwrap_or(0)
                    .max(self.inner.retry.backoff_ms(retries.min(63) as u32, sid)),
            );
            if Instant::now() + backoff >= deadline {
                return Err(ServeError::DeadlineExceeded {
                    elapsed_ms: start.elapsed().as_millis() as u64,
                    retries,
                });
            }
            thread::sleep(backoff);
            retries += 1;
        }
    }
}

impl Drop for Client {
    fn drop(&mut self) {
        self.inner.tenants.fetch_sub(1, Ordering::SeqCst);
    }
}

/// The dispatcher: wait for work, gather one tick's worth, drain,
/// process (rotations first, then coalesced solve panels). Exits when
/// the queue is stopped and empty.
fn dispatcher_loop(inner: &Inner) {
    loop {
        if inner.queue.wait_nonempty(Duration::from_millis(25)) {
            gather_tick(inner);
            let batch = inner.queue.drain();
            process_batch(inner, batch);
        } else if inner.queue.is_stopped() {
            // Anything admitted before stop() still gets an answer.
            let rest = inner.queue.drain();
            process_batch(inner, rest);
            break;
        }
    }
}

/// Sleep out the gathering window (stop-aware, chunked so shutdown
/// never waits a full tick).
fn gather_tick(inner: &Inner) {
    if inner.opts.tick_ms == 0 {
        return;
    }
    let deadline = Instant::now() + Duration::from_millis(inner.opts.tick_ms);
    loop {
        if inner.queue.is_stopped() {
            return;
        }
        let now = Instant::now();
        if now >= deadline {
            return;
        }
        thread::sleep((deadline - now).min(Duration::from_millis(5)));
    }
}

fn fatal_backend(e: &SolveError) -> bool {
    matches!(e, SolveError::Backend { retryable: false, .. })
}

/// Heal the worker pool, then rebuild this session's distributed state
/// from its durable record. Prefers the **replay** path — stage the
/// snapshot, redamp at the recorded λ, replay the rotation log through
/// the ordinary `update_rows` — which executes the same leader-side
/// arithmetic in the same order as the unfailed run. Falls back to a
/// **cold refactor** of the fully-materialized window when replay
/// itself fails (e.g. a worker died again mid-replay).
fn heal_and_rematerialize(inner: &Inner, sess: &mut TenantSession) -> Result<(), ServeError> {
    let report = inner.supervisor.heal(&inner.solver);
    inner.stats.lock().unwrap().worker_respawns += report.respawned as u64;
    let lambda = sess.record.lambda();
    let replayed = (|| -> Result<ShardedWindowSession, SolveError> {
        let mut fact =
            ShardedCholSolver::window_session(&inner.solver, sess.record.snapshot().clone());
        fact.redamp(lambda)?;
        for e in sess.record.log() {
            fact.update_rows(&e.removed, &e.added)?;
        }
        Ok(fact)
    })();
    match replayed {
        Ok(fact) => {
            // The broken session drops here; DropShard on a respawned
            // (empty) worker is a no-op ack.
            sess.fact = fact;
            inner.stats.lock().unwrap().session_replays += 1;
            Ok(())
        }
        Err(_) => {
            let window = sess.record.materialize_window().map_err(|e| {
                ServeError::Solver(SolveError::BadInput(format!("session record: {e}")))
            })?;
            let mut fact = ShardedCholSolver::window_session(&inner.solver, window);
            fact.redamp(lambda).map_err(ServeError::from)?;
            sess.fact = fact;
            inner.stats.lock().unwrap().session_refactors += 1;
            Ok(())
        }
    }
}

/// Graceful degradation: answer a panel from a leader-local Cholesky
/// of the recorded window when the distributed path cannot be
/// recovered in time. Slower (no sharding) but exactly the same
/// Algorithm-1 arithmetic — flagged via `ServeStats::local_fallbacks`.
fn local_fallback(
    inner: &Inner,
    sess: &TenantSession,
    g: &SolveGroup,
    panel: &Mat,
) -> Result<Mat, ServeError> {
    let window = sess
        .record
        .materialize_window()
        .map_err(|e| ServeError::Solver(SolveError::BadInput(format!("session record: {e}"))))?;
    let local = CholSolver::with_config(inner.opts.kernel);
    let l = local.gram_factor(&window, g.lambda)?;
    let mut xs = Mat::zeros(panel.rows(), panel.cols());
    for i in 0..panel.rows() {
        let x = local.solve_with_factor(&window, &l, panel.row(i), g.lambda);
        xs.row_mut(i).copy_from_slice(&x);
    }
    inner.stats.lock().unwrap().local_fallbacks += 1;
    Ok(xs)
}

/// Apply one rotation: `update_rows`, with one heal + re-materialize +
/// retry round on a fatal transport fault (safe because recovery
/// rebuilds the *pre-rotation* state from the record, so the retried
/// rotation applies exactly once). Success is logged into the session
/// record, refreshing the snapshot at the configured cadence.
fn apply_rotate_item(inner: &Inner, sess: &mut TenantSession, r: &RotateItem) -> Result<(), ServeError> {
    let mut res = sess.fact.update_rows(&r.removed, &r.added);
    if let Err(e) = &res {
        if inner.opts.supervise && fatal_backend(e) {
            heal_and_rematerialize(inner, sess)?;
            res = sess.fact.update_rows(&r.removed, &r.added);
        }
    }
    res.map_err(ServeError::from)?;
    if sess.record.record_rotation(&r.removed, &r.added, sess.fact.window()) {
        inner.stats.lock().unwrap().snapshots += 1;
    }
    inner.persist_record(r.sid, &sess.record);
    Ok(())
}

/// Solve one coalesced panel with the full fault policy: transient
/// faults retry under capped backoff (deadline-bounded), the first
/// fatal fault heals + re-materializes + retries, and a second fatal
/// round (or failed/late recovery) degrades to the leader-local path.
fn solve_group(inner: &Inner, sess: &mut TenantSession, g: &SolveGroup) -> Result<Mat, ServeError> {
    let m = sess.fact.dim();
    let k = g.rows.len();
    let mut data = Vec::with_capacity(k * m);
    for row in &g.rows {
        data.extend_from_slice(row);
    }
    let panel = Mat::from_vec(k, m, data);
    let mut attempt: u32 = 0;
    let mut healed = false;
    loop {
        if Instant::now() >= g.deadline {
            inner.stats.lock().unwrap().deadline_exceeded += k as u64;
            return Err(ServeError::DeadlineExceeded {
                elapsed_ms: g.enqueued.elapsed().as_millis() as u64,
                retries: u64::from(attempt),
            });
        }
        let res = (|| -> Result<Mat, SolveError> {
            if sess.fact.lambda().to_bits() != g.lambda.to_bits() {
                sess.fact.redamp(g.lambda)?;
                sess.record.set_lambda(g.lambda);
                inner.persist_record(g.sid, &sess.record);
            }
            sess.fact.solve_many(&panel)
        })();
        let e = match res {
            Ok(xs) => return Ok(xs),
            Err(e) => e,
        };
        if matches!(e, SolveError::Backend { retryable: true, .. })
            && attempt < inner.opts.max_retries
        {
            attempt += 1;
            inner.stats.lock().unwrap().backend_retries += 1;
            let sleep = Duration::from_millis(inner.retry.backoff_ms(attempt - 1, g.sid));
            thread::sleep(sleep.min(g.deadline.saturating_duration_since(Instant::now())));
            continue;
        }
        if !fatal_backend(&e) || !inner.opts.supervise {
            return Err(e.into());
        }
        if !healed {
            healed = true;
            if heal_and_rematerialize(inner, sess).is_ok() && Instant::now() < g.deadline {
                continue; // retry the panel against the recovered session
            }
        }
        return local_fallback(inner, sess, g, &panel);
    }
}

fn process_batch(inner: &Inner, batch: Vec<Pending>) {
    if batch.is_empty() {
        return;
    }
    let mut solves = Vec::new();
    let mut rotates = Vec::new();
    for p in batch {
        match p {
            Pending::Solve(s) => solves.push(s),
            Pending::Rotate(r) => rotates.push(r),
        }
    }
    // Requests that aged out while queued get their typed answer now
    // instead of burning backend work they can no longer use.
    let now = Instant::now();
    let mut expired = 0u64;
    rotates.retain(|r| {
        if now < r.deadline {
            return true;
        }
        expired += 1;
        let _ = r.reply.send(Err(ServeError::DeadlineExceeded {
            elapsed_ms: now.duration_since(r.enqueued).as_millis() as u64,
            retries: 0,
        }));
        false
    });
    solves.retain(|s| {
        if now < s.deadline {
            return true;
        }
        expired += 1;
        let _ = s.reply.send(Err(ServeError::DeadlineExceeded {
            elapsed_ms: now.duration_since(s.enqueued).as_millis() as u64,
            retries: 0,
        }));
        false
    });
    if expired > 0 {
        inner.stats.lock().unwrap().deadline_exceeded += expired;
    }
    let mut sessions = inner.sessions.lock().unwrap();

    // Rotations first, in arrival order: a tick's solves run against
    // the fully-rotated window.
    for r in rotates {
        let res = match sessions.get_mut(&r.sid) {
            None => Err(ServeError::UnknownSession(r.sid)),
            Some(sess) => apply_rotate_item(inner, sess, &r),
        };
        if res.is_ok() {
            inner.stats.lock().unwrap().rotations += 1;
        }
        let _ = r.reply.send(res);
    }

    // Coalesced solve panels: one redamp + one solve_many per
    // (session, λ) group.
    for g in coalesce_solves(solves, inner.opts.coalesce) {
        let k = g.rows.len();
        let Some(sess) = sessions.get_mut(&g.sid) else {
            for tx in g.replies {
                let _ = tx.send(Err(ServeError::UnknownSession(g.sid)));
            }
            continue;
        };
        match solve_group(inner, sess, &g) {
            Ok(xs) => {
                {
                    let mut st = inner.stats.lock().unwrap();
                    st.panels += 1;
                    st.completed += k as u64;
                    st.coalesced_rows += (k - 1) as u64;
                    st.max_panel_rows = st.max_panel_rows.max(k);
                }
                for (i, tx) in g.replies.into_iter().enumerate() {
                    let _ = tx.send(Ok(xs.row(i).to_vec()));
                }
            }
            Err(e) => {
                for tx in g.replies {
                    let _ = tx.send(Err(e.clone()));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;
    use crate::solver::CholSolver;

    fn quick_opts() -> ServeOptions {
        ServeOptions { workers: 2, worker_queue_depth: 4, tick_ms: 1, ..ServeOptions::default() }
    }

    fn reference_solve(s: &Mat, v: &[f64], lambda: f64) -> Vec<f64> {
        CholSolver::default().solve(s, v, lambda).unwrap()
    }

    #[test]
    fn serve_round_trip_matches_direct_solver() {
        let mut rng = Rng::seed_from(440);
        let s = Mat::randn(8, 40, &mut rng);
        let v: Vec<f64> = (0..40).map(|_| rng.normal()).collect();
        let server = Server::start(quick_opts()).unwrap();
        let client = server.client().unwrap();
        let sid = client.open_session(s.clone(), 0.1).unwrap();
        let x = client.solve(sid, 0.1, &v).unwrap();
        let x_ref = reference_solve(&s, &v, 0.1);
        for (a, b) in x.iter().zip(&x_ref) {
            assert!((a - b).abs() < 1e-9, "serve {a} vs direct {b}");
        }
        // λ-resweep through the serving path reuses the staging.
        let x2 = client.solve(sid, 0.05, &v).unwrap();
        let x2_ref = reference_solve(&s, &v, 0.05);
        for (a, b) in x2.iter().zip(&x2_ref) {
            assert!((a - b).abs() < 1e-9);
        }
        client.close_session(sid).unwrap();
        // Shutdown can only harvest worker counters once every client
        // handle (each holds the server state alive) is gone.
        drop(client);
        let stats = server.shutdown();
        assert_eq!(stats.completed, 2);
        assert!(!stats.worker_jobs.is_empty(), "shutdown must harvest worker counters");
    }

    #[test]
    fn tenant_slots_are_capped_and_released() {
        let opts = ServeOptions { tenants: 1, queue_depth: 4, ..quick_opts() };
        let server = Server::start(opts).unwrap();
        let c1 = server.client().unwrap();
        match server.client() {
            Err(ServeError::TenantLimit { tenants }) => assert_eq!(tenants, 1),
            _ => panic!("expected TenantLimit"),
        }
        assert!(ServeError::TenantLimit { tenants: 1 }.is_retryable());
        drop(c1);
        let _c2 = server.client().unwrap();
    }

    #[test]
    fn over_budget_sessions_are_rejected_with_hint() {
        // Budget sized for one session but not two: the second admit
        // must reject with the model's numbers, not OOM.
        let need = memory_bytes(SolverKind::Chol, 8, 40);
        let opts = ServeOptions {
            budget_gb: (need as f64) * 1.5 / 1e9,
            ..quick_opts()
        };
        let server = Server::start(opts).unwrap();
        let client = server.client().unwrap();
        let mut rng = Rng::seed_from(441);
        let s = Mat::randn(8, 40, &mut rng);
        let sid = client.open_session(s.clone(), 0.1).unwrap();
        // A second session exceeds the remaining budget.
        match client.open_session(s.clone(), 0.1) {
            Err(ServeError::OverBudget { required_bytes, budget_bytes, retry_after_ms }) => {
                assert_eq!(required_bytes, need);
                assert!(budget_bytes < need);
                assert!(retry_after_ms >= 1);
            }
            other => panic!("expected OverBudget, got {:?}", other.map(|_| ())),
        }
        // Closing releases the charge; admission succeeds again.
        client.close_session(sid).unwrap();
        let sid2 = client.open_session(s, 0.1).unwrap();
        client.close_session(sid2).unwrap();
    }

    #[test]
    fn unknown_sessions_and_bad_rhs_are_typed_errors() {
        let server = Server::start(quick_opts()).unwrap();
        let client = server.client().unwrap();
        match client.solve(99, 0.1, &[1.0; 4]) {
            Err(ServeError::UnknownSession(99)) => {}
            other => panic!("expected UnknownSession, got {:?}", other.map(|_| ())),
        }
        let mut rng = Rng::seed_from(442);
        let sid = client.open_session(Mat::randn(6, 30, &mut rng), 0.1).unwrap();
        match client.solve(sid, 0.1, &[1.0; 7]) {
            Err(ServeError::Solver(SolveError::BadInput(msg))) => {
                assert!(msg.contains("m = 30"), "{msg}");
            }
            other => panic!("expected BadInput, got {:?}", other.map(|_| ())),
        }
        assert!(client.solve(sid, -1.0, &[1.0; 30]).is_err());
    }

    #[test]
    fn coalescing_batches_concurrent_tenants_into_fewer_panels() {
        // Long tick so all async submissions land in one gathering
        // window → one panel for the shared (session, λ) group.
        let opts = ServeOptions { tick_ms: 60, ..quick_opts() };
        let server = Server::start(opts).unwrap();
        let client = server.client().unwrap();
        let mut rng = Rng::seed_from(443);
        let s = Mat::randn(8, 40, &mut rng);
        let sid = client.open_session(s.clone(), 0.1).unwrap();
        let vs: Vec<Vec<f64>> =
            (0..6).map(|_| (0..40).map(|_| rng.normal()).collect()).collect();
        let tickets: Vec<SolveTicket> =
            vs.iter().map(|v| client.solve_async(sid, 0.1, v).unwrap()).collect();
        for (t, v) in tickets.into_iter().zip(&vs) {
            let x = t.wait().unwrap();
            let x_ref = reference_solve(&s, v, 0.1);
            for (a, b) in x.iter().zip(&x_ref) {
                assert!((a - b).abs() < 1e-9, "coalesced answer must match per-RHS reference");
            }
        }
        let stats = server.shutdown();
        assert_eq!(stats.completed, 6);
        assert!(
            stats.panels < 6,
            "6 same-(sid, λ) requests in one tick must coalesce, got {} panels",
            stats.panels
        );
        assert_eq!(stats.coalesced_rows, 6 - stats.panels);
    }

    #[test]
    fn rotation_through_the_server_matches_cold_factor() {
        let mut rng = Rng::seed_from(444);
        let s = Mat::randn(8, 40, &mut rng);
        let server = Server::start(quick_opts()).unwrap();
        let client = server.client().unwrap();
        let sid = client.open_session(s.clone(), 0.1).unwrap();
        let added = Mat::randn(2, 40, &mut rng);
        client.rotate(sid, &[0, 3], added.clone()).unwrap();
        // Reference: hand-rotated window, cold factor.
        let mut rot = Mat::zeros(8, 40);
        let kept: Vec<usize> = (0..8).filter(|i| *i != 0 && *i != 3).collect();
        for (r, &i) in kept.iter().enumerate() {
            for j in 0..40 {
                rot[(r, j)] = s[(i, j)];
            }
        }
        for r in 0..2 {
            for j in 0..40 {
                rot[(6 + r, j)] = added[(r, j)];
            }
        }
        let v: Vec<f64> = (0..40).map(|_| rng.normal()).collect();
        let x = client.solve(sid, 0.1, &v).unwrap();
        let x_ref = reference_solve(&rot, &v, 0.1);
        for (a, b) in x.iter().zip(&x_ref) {
            assert!((a - b).abs() < 1e-9, "rotated serve {a} vs cold {b}");
        }
        let stats = server.shutdown();
        assert_eq!(stats.rotations, 1);
    }

    #[test]
    fn full_queue_rejects_with_overloaded() {
        // queue_depth = tenants = 1 and a long tick: the second async
        // submit within one tick finds the queue full.
        let opts = ServeOptions { tenants: 1, queue_depth: 1, tick_ms: 200, ..quick_opts() };
        let server = Server::start(opts).unwrap();
        let client = server.client().unwrap();
        let mut rng = Rng::seed_from(445);
        let sid = client.open_session(Mat::randn(6, 30, &mut rng), 0.1).unwrap();
        let v = vec![1.0; 30];
        let mut saw_overloaded = false;
        let mut tickets = Vec::new();
        for _ in 0..3 {
            match client.solve_async(sid, 0.1, &v) {
                Ok(t) => tickets.push(t),
                Err(ServeError::Overloaded { retry_after_ms }) => {
                    assert!(retry_after_ms >= 1);
                    saw_overloaded = true;
                    break;
                }
                Err(e) => panic!("unexpected error: {e}"),
            }
        }
        assert!(saw_overloaded, "depth-1 queue must reject within one tick");
        for t in tickets {
            t.wait().unwrap();
        }
        let stats = server.shutdown();
        assert!(stats.rejected >= 1);
    }

    #[test]
    fn shutdown_answers_queued_work_then_rejects() {
        let opts = ServeOptions { tick_ms: 50, ..quick_opts() };
        let server = Server::start(opts).unwrap();
        let client = server.client().unwrap();
        let mut rng = Rng::seed_from(446);
        let s = Mat::randn(6, 30, &mut rng);
        let sid = client.open_session(s.clone(), 0.1).unwrap();
        let v: Vec<f64> = (0..30).map(|_| rng.normal()).collect();
        let t = client.solve_async(sid, 0.1, &v).unwrap();
        let stats = server.shutdown();
        // The in-flight request was answered, not dropped.
        let x = t.wait().unwrap();
        let x_ref = reference_solve(&s, &v, 0.1);
        for (a, b) in x.iter().zip(&x_ref) {
            assert!((a - b).abs() < 1e-9);
        }
        assert_eq!(stats.completed, 1);
        // Post-shutdown submissions are typed rejections.
        match client.solve_async(sid, 0.1, &v) {
            Err(ServeError::ShuttingDown) => {}
            other => panic!("expected ShuttingDown, got {:?}", other.map(|_| ()).err()),
        }
    }

    #[test]
    fn options_validate_rejects_bad_shapes() {
        assert!(ServeOptions { tenants: 0, ..ServeOptions::default() }.validate().is_err());
        assert!(ServeOptions { tenants: 8, queue_depth: 4, ..ServeOptions::default() }
            .validate()
            .is_err());
        assert!(ServeOptions { budget_gb: -1.0, ..ServeOptions::default() }.validate().is_err());
        assert!(ServeOptions { workers: 0, ..ServeOptions::default() }.validate().is_err());
        assert!(ServeOptions { deadline_ms: 0, ..ServeOptions::default() }.validate().is_err());
        assert!(ServeOptions { deadline_ms: 600_001, ..ServeOptions::default() }
            .validate()
            .is_err());
        assert!(ServeOptions { snapshot_every: 0, ..ServeOptions::default() }.validate().is_err());
        ServeOptions::default().validate().unwrap();
    }

    #[test]
    fn killed_worker_recovers_transparently_via_replay() {
        let mut rng = Rng::seed_from(447);
        let s = Mat::randn(8, 40, &mut rng);
        let v: Vec<f64> = (0..40).map(|_| rng.normal()).collect();
        let server = Server::start(quick_opts()).unwrap();
        let client = server.client().unwrap();
        let sid = client.open_session(s.clone(), 0.1).unwrap();
        let x0 = client.solve(sid, 0.1, &v).unwrap();
        server.inject_kill(0);
        let x1 = client.solve(sid, 0.1, &v).unwrap();
        for (a, b) in x1.iter().zip(&x0) {
            assert!((a - b).abs() < 1e-9, "recovered {a} vs pre-fault {b}");
        }
        let x_ref = reference_solve(&s, &v, 0.1);
        for (a, b) in x1.iter().zip(&x_ref) {
            assert!((a - b).abs() < 1e-9, "recovered {a} vs direct {b}");
        }
        client.close_session(sid).unwrap();
        assert_eq!(server.live_sessions(), 0);
        assert_eq!(server.admitted_bytes(), 0);
        drop(client);
        let stats = server.shutdown();
        assert_eq!(stats.worker_respawns, 1, "exactly one worker died: {stats:?}");
        assert_eq!(stats.session_replays, 1, "recovery must take the replay path: {stats:?}");
        assert_eq!(stats.local_fallbacks, 0, "distributed recovery must suffice: {stats:?}");
        assert_eq!(stats.completed, 2);
    }

    #[test]
    fn rotation_after_kill_replays_the_log_then_applies_once() {
        let mut rng = Rng::seed_from(448);
        let s = Mat::randn(8, 40, &mut rng);
        let server = Server::start(quick_opts()).unwrap();
        let client = server.client().unwrap();
        let sid = client.open_session(s.clone(), 0.1).unwrap();
        let a1 = Mat::randn(1, 40, &mut rng);
        let a2 = Mat::randn(2, 40, &mut rng);
        client.rotate(sid, &[0], a1.clone()).unwrap();
        server.inject_kill(1);
        client.rotate(sid, &[2, 4], a2.clone()).unwrap();
        // Reference: both rotations applied by hand, cold factor.
        let rot = |w: &Mat, removed: &[usize], added: &Mat| -> Mat {
            let kept: Vec<usize> =
                (0..w.rows()).filter(|i| !removed.contains(i)).collect();
            let mut out = Mat::zeros(kept.len() + added.rows(), w.cols());
            for (dst, &src) in kept.iter().enumerate() {
                out.row_mut(dst).copy_from_slice(w.row(src));
            }
            for r in 0..added.rows() {
                out.row_mut(kept.len() + r).copy_from_slice(added.row(r));
            }
            out
        };
        let w_ref = rot(&rot(&s, &[0], &a1), &[2, 4], &a2);
        let v: Vec<f64> = (0..40).map(|_| rng.normal()).collect();
        let x = client.solve(sid, 0.1, &v).unwrap();
        let x_ref = reference_solve(&w_ref, &v, 0.1);
        for (a, b) in x.iter().zip(&x_ref) {
            assert!((a - b).abs() < 1e-9, "post-recovery rotate {a} vs cold {b}");
        }
        client.close_session(sid).unwrap();
        drop(client);
        let stats = server.shutdown();
        assert_eq!(stats.rotations, 2, "the retried rotation applies exactly once: {stats:?}");
        assert_eq!(stats.worker_respawns, 1);
        assert_eq!(stats.session_replays, 1);
    }

    #[test]
    fn supervision_off_preserves_typed_fatal_errors() {
        let opts = ServeOptions { supervise: false, ..quick_opts() };
        let server = Server::start(opts).unwrap();
        let client = server.client().unwrap();
        let mut rng = Rng::seed_from(449);
        let sid = client.open_session(Mat::randn(6, 30, &mut rng), 0.1).unwrap();
        server.inject_kill(0);
        match client.solve(sid, 0.1, &[1.0; 30]) {
            Err(ServeError::Solver(SolveError::Backend { retryable: false, .. })) => {}
            other => panic!("expected fatal Backend, got {:?}", other.map(|_| ())),
        }
        let stats = server.stats();
        assert_eq!(stats.worker_respawns, 0);
        assert_eq!(stats.local_fallbacks, 0);
    }

    #[test]
    fn expired_requests_get_deadline_exceeded_with_progress() {
        // 1 ms deadline, 50 ms gathering tick: the request ages out in
        // the queue and must be answered typed, not solved.
        let opts = ServeOptions { deadline_ms: 1, tick_ms: 50, ..quick_opts() };
        let server = Server::start(opts).unwrap();
        let client = server.client().unwrap();
        let mut rng = Rng::seed_from(450);
        let sid = client.open_session(Mat::randn(6, 30, &mut rng), 0.1).unwrap();
        let t = client.solve_async(sid, 0.1, &[1.0; 30]).unwrap();
        match t.wait() {
            Err(ServeError::DeadlineExceeded { elapsed_ms, retries }) => {
                assert!(elapsed_ms >= 1, "progress stats must carry time in flight");
                assert_eq!(retries, 0);
            }
            other => panic!("expected DeadlineExceeded, got {:?}", other.map(|_| ())),
        }
        let stats = server.stats();
        assert_eq!(stats.deadline_exceeded, 1);
        assert_eq!(stats.completed, 0);
    }

    #[test]
    fn session_records_spill_and_vacate_record_dir() {
        let dir = std::env::temp_dir().join("dngd_test_serve_records");
        let _ = std::fs::remove_dir_all(&dir);
        let opts = ServeOptions {
            snapshot_every: 2,
            record_dir: dir.to_string_lossy().into_owned(),
            ..quick_opts()
        };
        let server = Server::start(opts).unwrap();
        let client = server.client().unwrap();
        let mut rng = Rng::seed_from(451);
        let s = Mat::randn(6, 30, &mut rng);
        let sid = client.open_session(s, 0.1).unwrap();
        let path = dir.join(format!("session-{sid}.ckpt"));
        assert!(path.exists(), "open must cut a durable record");
        let add = Mat::randn(1, 30, &mut rng);
        client.rotate(sid, &[0], add.clone()).unwrap();
        let rec = SessionRecord::load(&path).unwrap();
        assert_eq!(rec.replay_len(), 1, "one rotation since snapshot");
        client.rotate(sid, &[1], add).unwrap();
        let rec = SessionRecord::load(&path).unwrap();
        assert_eq!(rec.replay_len(), 0, "cadence 2 must refresh the snapshot");
        assert_eq!(server.stats().snapshots, 1);
        client.close_session(sid).unwrap();
        assert!(!path.exists(), "close must remove the record");
        drop(client);
        server.shutdown();
        let _ = std::fs::remove_dir_all(&dir);
    }
}
