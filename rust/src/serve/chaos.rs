//! Chaos harness (PR 8): scripted fault schedules against a live
//! [`Server`], asserting every schedule ends with **correct answers**
//! (≤ 1e-9 against a leader-local reference that never sees a fault)
//! and **zero leaked sessions or budget bytes**. The recovery path the
//! server took (replay vs refactor vs local fallback, respawn counts)
//! is observable in the returned [`ServeStats`], so schedules can pin
//! it.
//!
//! The CLI front door is `dngd chaos` (`--schedule`, `--transport`,
//! `--seed`, `--requests`); the soak test in `tests/serving.rs` runs
//! every schedule over both transports at 1 and 8 kernel threads.

use super::server::{ServeOptions, ServeStats, Server};
use super::transport::TransportKind;
use crate::data::rng::Rng;
use crate::linalg::{KernelConfig, Mat};
use crate::solver::CholSolver;

/// A scripted fault schedule. Each one targets a distinct layer of the
/// fault machinery; all of them must end with correct answers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultSchedule {
    /// Kill a worker, then immediately demand a λ change so the next
    /// request drives recovery through the *factor* path (heal →
    /// replay → redamp at the new λ).
    KillDuringFactor,
    /// Periodically stall workers mid-traffic. Stalls add latency but
    /// workers stay healthy — the supervisor must NOT respawn anyone.
    StallDuringPanel,
    /// Corrupt a length prefix at the framing layer (socket transport;
    /// degrades to a kill on channels, which have no frames). The demux
    /// goes fatal and recovery reconnects.
    CorruptFrame,
    /// Kill a rotating worker every `kill_every` requests — sustained
    /// respawn pressure with sessions re-materialized each time.
    RespawnStorm,
}

impl FaultSchedule {
    pub fn all() -> [FaultSchedule; 4] {
        [
            FaultSchedule::KillDuringFactor,
            FaultSchedule::StallDuringPanel,
            FaultSchedule::CorruptFrame,
            FaultSchedule::RespawnStorm,
        ]
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            FaultSchedule::KillDuringFactor => "kill-during-factor",
            FaultSchedule::StallDuringPanel => "stall-during-panel",
            FaultSchedule::CorruptFrame => "corrupt-frame",
            FaultSchedule::RespawnStorm => "respawn-storm",
        }
    }

    pub fn parse(s: &str) -> Result<FaultSchedule, String> {
        match s {
            "kill-during-factor" => Ok(FaultSchedule::KillDuringFactor),
            "stall-during-panel" => Ok(FaultSchedule::StallDuringPanel),
            "corrupt-frame" => Ok(FaultSchedule::CorruptFrame),
            "respawn-storm" => Ok(FaultSchedule::RespawnStorm),
            other => Err(format!(
                "unknown chaos schedule {other:?} (want kill-during-factor | \
                 stall-during-panel | corrupt-frame | respawn-storm | all)"
            )),
        }
    }
}

impl std::fmt::Display for FaultSchedule {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Harness knobs (`chaos.*` config keys + CLI flags).
#[derive(Debug, Clone, PartialEq)]
pub struct ChaosOptions {
    pub transport: TransportKind,
    /// Kernel threads for the dense stages (the soak test runs 1 and 8).
    pub threads: usize,
    pub workers: usize,
    pub seed: u64,
    /// Solve requests per schedule run.
    pub requests: usize,
    /// Kill cadence for [`FaultSchedule::RespawnStorm`].
    pub kill_every: usize,
}

impl Default for ChaosOptions {
    fn default() -> Self {
        ChaosOptions {
            transport: TransportKind::Channels,
            threads: 1,
            workers: 2,
            seed: 4242,
            requests: 40,
            kill_every: 10,
        }
    }
}

/// What one schedule run produced. `passed` folds the correctness
/// gate, the leak checks, and the schedule-specific counter
/// assertions; `detail` says which one failed (empty when green).
#[derive(Debug, Clone)]
pub struct ChaosReport {
    pub schedule: &'static str,
    pub transport: &'static str,
    pub requests: usize,
    /// Worst per-request error vs the fault-free leader-local
    /// reference, scaled by the reference's magnitude.
    pub max_rel_err: f64,
    pub stats: ServeStats,
    pub leaked_sessions: usize,
    pub leaked_bytes: u64,
    pub passed: bool,
    pub detail: String,
}

/// Leader-side reference rotation: kept rows in order, added appended —
/// the same semantics as the distributed `update_rows`.
fn rotate_reference(w: &Mat, removed: &[usize], added: &Mat) -> Mat {
    let kept: Vec<usize> = (0..w.rows()).filter(|i| !removed.contains(i)).collect();
    let mut out = Mat::zeros(kept.len() + added.rows(), w.cols());
    for (dst, &src) in kept.iter().enumerate() {
        out.row_mut(dst).copy_from_slice(w.row(src));
    }
    for r in 0..added.rows() {
        out.row_mut(kept.len() + r).copy_from_slice(added.row(r));
    }
    out
}

/// Run one fault schedule against a fresh server and judge the run.
///
/// The workload is seeded and identical across schedules: a sliding
/// window of scores, solves alternating between two λ values, and a
/// rotation every fifth request. A fault-free [`CholSolver`] tracking
/// the same window supplies the reference answer for every request.
pub fn run_schedule(
    schedule: FaultSchedule,
    opts: &ChaosOptions,
) -> Result<ChaosReport, String> {
    let (n, m) = (10usize, 48usize);
    let lambdas = [1e-2, 5e-2];
    let serve_opts = ServeOptions {
        transport: opts.transport,
        workers: opts.workers,
        kernel: KernelConfig::with_threads(opts.threads),
        tick_ms: 1,
        snapshot_every: 4,
        ..ServeOptions::default()
    };
    let server = Server::start(serve_opts)?;
    let client = server.client().map_err(|e| format!("chaos: connect: {e}"))?;
    let reference = CholSolver::with_config(KernelConfig::with_threads(opts.threads));

    let mut rng = Rng::seed_from(opts.seed);
    let mut window = Mat::randn(n, m, &mut rng);
    let sid = client
        .open_session(window.clone(), lambdas[0])
        .map_err(|e| format!("chaos: open: {e}"))?;

    let mut max_rel_err = 0.0f64;
    let mut kills = 0u64;
    let mut failures: Vec<String> = Vec::new();
    let kill_at = opts.requests / 3;
    for i in 0..opts.requests {
        // Fault injection, per schedule.
        match schedule {
            FaultSchedule::KillDuringFactor => {
                if i == kill_at {
                    server.inject_kill(i % opts.workers);
                    kills += 1;
                }
            }
            FaultSchedule::StallDuringPanel => {
                if i % 7 == 3 {
                    server.inject_stall(i % opts.workers, 20);
                }
            }
            FaultSchedule::CorruptFrame => {
                if i == kill_at && !server.inject_corrupt_frame(i % opts.workers) {
                    // Channels have no frames to corrupt; the schedule
                    // degrades to a kill so both transports stay green.
                    server.inject_kill(i % opts.workers);
                }
                if i == kill_at {
                    kills += 1;
                }
            }
            FaultSchedule::RespawnStorm => {
                if opts.kill_every > 0 && i % opts.kill_every == opts.kill_every - 1 {
                    server.inject_kill(i % opts.workers);
                    kills += 1;
                }
            }
        }
        // λ alternates every request, so every solve re-factors — the
        // kill schedules therefore always die "during factor" from the
        // session's point of view.
        let lambda = lambdas[i % 2];
        // Rotation every fifth request (keeps the window at n rows).
        if i % 5 == 4 {
            let added = Mat::randn(1, m, &mut rng);
            let removed = [i % window.rows()];
            client
                .rotate(sid, &removed, added.clone())
                .map_err(|e| format!("chaos {schedule}: rotate {i}: {e}"))?;
            window = rotate_reference(&window, &removed, &added);
        }
        let v: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
        let x = client
            .solve(sid, lambda, &v)
            .map_err(|e| format!("chaos {schedule}: solve {i}: {e}"))?;
        let x_ref = reference
            .solve(&window, &v, lambda)
            .map_err(|e| format!("chaos {schedule}: reference {i}: {e}"))?;
        let scale = x_ref.iter().fold(1.0f64, |a, b| a.max(b.abs()));
        let err = x
            .iter()
            .zip(&x_ref)
            .fold(0.0f64, |a, (p, q)| a.max((p - q).abs()))
            / scale;
        max_rel_err = max_rel_err.max(err);
    }

    client.close_session(sid).map_err(|e| format!("chaos: close: {e}"))?;
    let leaked_sessions = server.live_sessions();
    let leaked_bytes = server.admitted_bytes();
    drop(client);
    let stats = server.shutdown();

    if max_rel_err > 1e-9 {
        failures.push(format!("max_rel_err {max_rel_err:.2e} > 1e-9"));
    }
    if leaked_sessions != 0 || leaked_bytes != 0 {
        failures.push(format!(
            "leaked {leaked_sessions} sessions / {leaked_bytes} budget bytes"
        ));
    }
    if stats.completed != opts.requests as u64 {
        failures.push(format!(
            "completed {} of {} requests",
            stats.completed, opts.requests
        ));
    }
    match schedule {
        FaultSchedule::StallDuringPanel => {
            if stats.worker_respawns != 0 {
                failures.push(format!(
                    "stalls must not trigger respawns, saw {}",
                    stats.worker_respawns
                ));
            }
        }
        _ => {
            if stats.worker_respawns != kills {
                failures.push(format!(
                    "injected {kills} kills but saw {} respawns",
                    stats.worker_respawns
                ));
            }
            if stats.session_replays + stats.session_refactors + stats.local_fallbacks < kills {
                failures.push(format!(
                    "{kills} kills need ≥ {kills} recoveries, saw replays {} + refactors {} + \
                     fallbacks {}",
                    stats.session_replays, stats.session_refactors, stats.local_fallbacks
                ));
            }
        }
    }

    Ok(ChaosReport {
        schedule: schedule.as_str(),
        transport: opts.transport.as_str(),
        requests: opts.requests,
        max_rel_err,
        stats,
        leaked_sessions,
        leaked_bytes,
        passed: failures.is_empty(),
        detail: failures.join("; "),
    })
}

/// Run every schedule with the given options; any setup error is a
/// hard failure (fault handling itself never errors the harness).
pub fn run_all(opts: &ChaosOptions) -> Result<Vec<ChaosReport>, String> {
    FaultSchedule::all().iter().map(|s| run_schedule(*s, opts)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedule_names_round_trip() {
        for s in FaultSchedule::all() {
            assert_eq!(FaultSchedule::parse(s.as_str()).unwrap(), s);
        }
        assert!(FaultSchedule::parse("segfault").is_err());
    }

    #[test]
    fn kill_during_factor_recovers_on_channels() {
        let opts = ChaosOptions { requests: 12, ..ChaosOptions::default() };
        let report = run_schedule(FaultSchedule::KillDuringFactor, &opts).unwrap();
        assert!(report.passed, "{}: {}", report.schedule, report.detail);
        assert_eq!(report.stats.worker_respawns, 1);
        assert_eq!(report.leaked_sessions, 0);
    }

    #[test]
    fn stalls_do_not_trigger_respawns() {
        let opts = ChaosOptions { requests: 12, ..ChaosOptions::default() };
        let report = run_schedule(FaultSchedule::StallDuringPanel, &opts).unwrap();
        assert!(report.passed, "{}: {}", report.schedule, report.detail);
        assert_eq!(report.stats.worker_respawns, 0);
    }
}
