//! Pluggable shard-worker transport (PR 7).
//!
//! The coordinator's workers used to be reachable only as in-process
//! threads behind `mpsc` channels. The serving layer abstracts that hop
//! behind [`ShardTransport`] so the same leader-side Algorithm-1 phases
//! (`coordinator/sharded.rs`) can drive workers that live **in-process**
//! ([`ChannelTransport`], the original pool) or **out-of-process** over
//! length-prefixed Unix-domain-socket frames ([`SocketTransport`]).
//!
//! Bit-identity contract: both transports funnel every request through
//! the single `execute_request` compute path, the wire codec round-
//! trips `f64` via `to_le_bytes` (bit-exact), and the leader collects
//! replies in worker order — so a solve through the socket transport is
//! **bit-identical** to the same solve through the channel transport at
//! every thread count within an ISA tier (asserted in
//! `rust/tests/serving.rs`).
//!
//! Requests are keyed by a **session id** (`sid`): each worker holds one
//! column shard *per live session*, which is what lets the serving layer
//! multiplex many tenants' sessions onto one worker set (the old pool
//! held exactly one shard and therefore one live session).
//!
//! Error taxonomy (the satellite-2 fix): [`TransportError::Retryable`]
//! is a transient infrastructure condition (full bounded queue — back
//! off and resubmit), [`TransportError::Fatal`] means the worker is gone
//! (dead thread, closed socket) and this transport will not heal. The
//! sharded session maps these onto `SolveError::Backend { retryable }`
//! without discarding its cached plan/Gram, so a failed call never
//! poisons the session state.

use crate::coordinator::pool::{Job, PoolError, WorkerPool};
use crate::linalg::gemm::{gemm_nt_threaded, gemm_tn_threaded, syrk_parallel};
use crate::linalg::{KernelConfig, Mat};
use std::collections::HashMap;
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::time::Duration;

/// Transport-level failure, split by whether retrying the same call on
/// the same transport can ever succeed.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum TransportError {
    /// Transient: the worker is alive but its bounded queue is full (or
    /// a bounded wait elapsed). Back off and resubmit (the serving
    /// layer turns this into a reject-with-retry-after).
    Retryable(String),
    /// The worker is gone — dead thread or closed connection. Retrying
    /// on this transport fails forever until the worker is
    /// [`ShardTransport::recover`]ed.
    Fatal(String),
    /// The encoded request exceeds the wire frame cap — sending it
    /// would be rejected (and the connection dropped) on the remote
    /// side, so it is refused before any bytes move. Not retryable:
    /// the same payload will always be too large.
    FrameTooLarge { len: u64, max: u64 },
}

impl TransportError {
    pub fn is_retryable(&self) -> bool {
        matches!(self, TransportError::Retryable(_))
    }
}

impl std::fmt::Display for TransportError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TransportError::Retryable(d) => write!(f, "transport busy (retryable): {d}"),
            TransportError::Fatal(d) => write!(f, "transport failed: {d}"),
            TransportError::FrameTooLarge { len, max } => {
                write!(f, "frame of {len} bytes exceeds the {max}-byte transport limit")
            }
        }
    }
}

impl std::error::Error for TransportError {}

/// Which transport backs a sharded solver — the `serve.transport`
/// config key.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TransportKind {
    /// In-process worker threads behind bounded `mpsc` channels.
    Channels,
    /// Out-of-process-style workers behind length-prefixed
    /// Unix-domain-socket frames.
    Socket,
}

impl TransportKind {
    pub fn parse(s: &str) -> Result<TransportKind, String> {
        match s {
            "channels" => Ok(TransportKind::Channels),
            "socket" => Ok(TransportKind::Socket),
            other => Err(format!(
                "unknown transport '{other}' (expected one of: channels, socket)"
            )),
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            TransportKind::Channels => "channels",
            TransportKind::Socket => "socket",
        }
    }
}

impl std::fmt::Display for TransportKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// One request to a shard worker. Every variant is answered by exactly
/// one [`ShardResponse`] (except [`ShardRequest::Die`], which simulates
/// a crash: the worker exits without replying and in-flight tickets
/// surface [`TransportError::Fatal`]).
#[derive(Debug, Clone)]
pub enum ShardRequest {
    /// Install session `sid`'s column shard (n × shard_width) on this
    /// worker, replacing any previous shard for the same session.
    SetShard { sid: u64, shard: Mat },
    /// Free session `sid`'s shard (session teardown).
    DropShard { sid: u64 },
    /// Partial Gram `S_k S_kᵀ` for session `sid` (un-damped — the
    /// leader adds λ when refactoring).
    Gram { sid: u64 },
    /// Batched partial matvec: `U_k = S_k·V_kᵀ` (n × k) for a k-RHS
    /// column panel `V_k` (k × shard_width).
    MatvecMany { sid: u64, v_k: Mat },
    /// Batched Algorithm-1 line 4: `X_k = (V_k − (S_kᵀZ)ᵀ)/λ`
    /// (k × shard_width).
    ApplyMany { sid: u64, z: Mat, v_k: Mat, lambda: f64 },
    /// Streaming rotation (PR-5 semantics, distributed): delete the
    /// sorted window rows `removed` from the shard, append the rows of
    /// `added_k` (k_add × shard_width), and reply the partial cross
    /// panel `P_k = S_kept,k · A_kᵀ` (n_kept × k_add) the leader needs
    /// to patch its cached Gram.
    UpdateRows { sid: u64, removed: Vec<usize>, added_k: Mat },
    /// Fault injection: sleep before the next request (straggler).
    Stall { ms: u64 },
    /// Liveness probe / FIFO barrier primitive.
    Ping,
    /// Fault injection: exit without replying (crash simulation).
    Die,
}

/// A worker's answer to one [`ShardRequest`].
#[derive(Debug, Clone, PartialEq)]
pub enum ShardResponse {
    Ack,
    Mat(Mat),
    /// Semantic/protocol error on the worker (e.g. no shard installed
    /// for the requested session) — always fatal, never retryable.
    Err(String),
    /// Processed-request counter, replied to the transport-internal
    /// shutdown frame.
    Count(u64),
}

/// The single compute path both transports execute per request — this
/// sharing (plus the bit-exact wire codec and worker-ordered reply
/// collection) is what makes channel and socket solves bit-identical.
pub(crate) fn execute_request(
    shards: &mut HashMap<u64, Mat>,
    req: ShardRequest,
    kernel: KernelConfig,
) -> ShardResponse {
    match req {
        ShardRequest::SetShard { sid, shard } => {
            shards.insert(sid, shard);
            ShardResponse::Ack
        }
        ShardRequest::DropShard { sid } => {
            shards.remove(&sid);
            ShardResponse::Ack
        }
        ShardRequest::Gram { sid } => {
            let Some(s) = shards.get(&sid) else {
                return missing(sid);
            };
            ShardResponse::Mat(kernel.run(|| syrk_parallel(s, 0.0, kernel.threads)))
        }
        ShardRequest::MatvecMany { sid, v_k } => {
            let Some(s) = shards.get(&sid) else {
                return missing(sid);
            };
            // U_k = S_k·V_kᵀ (n × k): one panel GEMM on the worker's
            // kernel configuration.
            let mut u = Mat::zeros(s.rows(), v_k.rows());
            kernel.run(|| gemm_nt_threaded(1.0, s, &v_k, 0.0, &mut u, kernel.threads));
            ShardResponse::Mat(u)
        }
        ShardRequest::ApplyMany { sid, z, v_k, lambda } => {
            let Some(s) = shards.get(&sid) else {
                return missing(sid);
            };
            // T = S_kᵀ·Z (shard_width × k), then the Algorithm-1
            // line-4 combination per right-hand side.
            let (k, w) = v_k.shape();
            let mut t = Mat::zeros(w, k);
            kernel.run(|| gemm_tn_threaded(1.0, s, &z, 0.0, &mut t, kernel.threads));
            let inv = 1.0 / lambda;
            let mut x_k = Mat::zeros(k, w);
            for r in 0..k {
                let vrow = v_k.row(r);
                let xrow = x_k.row_mut(r);
                for j in 0..w {
                    xrow[j] = inv * (vrow[j] - t[(j, r)]);
                }
            }
            ShardResponse::Mat(x_k)
        }
        ShardRequest::UpdateRows { sid, removed, added_k } => {
            let Some(s) = shards.get_mut(&sid) else {
                return missing(sid);
            };
            let n = s.rows();
            let w = s.cols();
            if removed.windows(2).any(|p| p[0] >= p[1]) || removed.iter().any(|&r| r >= n) {
                return ShardResponse::Err(format!(
                    "update_rows: removal indices must be strictly increasing and < {n}"
                ));
            }
            let k_add = added_k.rows();
            if k_add > 0 && added_k.cols() != w {
                return ShardResponse::Err(format!(
                    "update_rows: added shard has {} cols, shard has {w}",
                    added_k.cols()
                ));
            }
            let mut rem = removed.iter().copied().peekable();
            let kept: Vec<usize> = (0..n)
                .filter(|&r| {
                    if rem.peek() == Some(&r) {
                        rem.next();
                        false
                    } else {
                        true
                    }
                })
                .collect();
            let n_kept = kept.len();
            let mut rotated = Mat::zeros(n_kept + k_add, w);
            for (dst, &src) in kept.iter().enumerate() {
                rotated.row_mut(dst).copy_from_slice(s.row(src));
            }
            for r in 0..k_add {
                rotated.row_mut(n_kept + r).copy_from_slice(added_k.row(r));
            }
            // Partial cross panel P_k = S_kept,k · A_kᵀ for the
            // leader's bordered-Gram patch.
            let mut p = Mat::zeros(n_kept, k_add);
            if n_kept > 0 && k_add > 0 {
                let kept_mat = rotated.slice_rows(0, n_kept);
                kernel
                    .run(|| gemm_nt_threaded(1.0, &kept_mat, &added_k, 0.0, &mut p, kernel.threads));
            }
            *s = rotated;
            ShardResponse::Mat(p)
        }
        ShardRequest::Stall { ms } => {
            std::thread::sleep(std::time::Duration::from_millis(ms));
            ShardResponse::Ack
        }
        ShardRequest::Ping => ShardResponse::Ack,
        // Die is intercepted by the transport loops before reaching the
        // compute path; answering Ack here keeps the function total.
        ShardRequest::Die => ShardResponse::Ack,
    }
}

fn missing(sid: u64) -> ShardResponse {
    ShardResponse::Err(format!("no shard installed for session {sid}"))
}

/// Handle for one in-flight request; [`ReplyTicket::wait`] blocks until
/// the worker's response arrives. Tickets are demuxed per request, so
/// multiple leader threads can have requests in flight on one worker
/// concurrently without interleaving each other's replies.
pub struct ReplyTicket {
    rx: Receiver<ShardResponse>,
    worker: usize,
}

impl ReplyTicket {
    pub(crate) fn new(rx: Receiver<ShardResponse>, worker: usize) -> ReplyTicket {
        ReplyTicket { rx, worker }
    }

    /// Block for the response. A closed reply channel means the worker
    /// died (or its connection dropped) with the request in flight —
    /// fatal for this transport.
    pub fn wait(self) -> Result<ShardResponse, TransportError> {
        self.rx.recv().map_err(|_| {
            TransportError::Fatal(format!(
                "worker {}: reply channel closed (worker or connection down)",
                self.worker
            ))
        })
    }

    /// Bounded [`ReplyTicket::wait`]: an elapsed timeout is *retryable*
    /// (the worker may merely be slow — e.g. a straggler mid-stall),
    /// a closed channel is fatal exactly as in `wait`. The supervisor's
    /// liveness probe rides on this distinction.
    pub fn wait_timeout(self, timeout: Duration) -> Result<ShardResponse, TransportError> {
        match self.rx.recv_timeout(timeout) {
            Ok(resp) => Ok(resp),
            Err(RecvTimeoutError::Timeout) => Err(TransportError::Retryable(format!(
                "worker {}: no reply within {timeout:?}",
                self.worker
            ))),
            Err(RecvTimeoutError::Disconnected) => Err(TransportError::Fatal(format!(
                "worker {}: reply channel closed (worker or connection down)",
                self.worker
            ))),
        }
    }
}

/// Leader-side view of a set of shard workers. Implementations must be
/// safe to share across leader threads (`Send + Sync`): requests from
/// different threads may interleave arbitrarily and are demuxed per
/// [`ReplyTicket`].
pub trait ShardTransport: Send + Sync {
    fn name(&self) -> &'static str;

    fn workers(&self) -> usize;

    /// Enqueue `req` on worker `w`; blocks while the worker's queue is
    /// full (backpressure). Fails fatally when the worker is gone.
    fn request(&self, w: usize, req: ShardRequest) -> Result<ReplyTicket, TransportError>;

    /// Non-blocking [`ShardTransport::request`]: a full queue surfaces
    /// as [`TransportError::Retryable`] instead of blocking.
    fn try_request(&self, w: usize, req: ShardRequest) -> Result<ReplyTicket, TransportError>;

    /// FIFO barrier: returns once every request enqueued before the
    /// call has been processed on every worker.
    fn flush(&self) -> Result<(), TransportError>;

    /// Liveness probe: one `Ping` round trip bounded by `timeout`.
    /// `true` means the worker answered (or is merely backed up — a
    /// full queue is proof of life); `false` means it is dead or wedged
    /// past the timeout and needs [`ShardTransport::recover`].
    fn probe(&self, w: usize, timeout: Duration) -> bool {
        match self.try_request(w, ShardRequest::Ping) {
            Ok(ticket) => matches!(ticket.wait_timeout(timeout), Ok(ShardResponse::Ack)),
            Err(TransportError::Retryable(_)) => true,
            Err(_) => false,
        }
    }

    /// Replace or reconnect dead worker `w` so the slot can serve
    /// again. The revived worker starts with an **empty** shard map:
    /// every session it hosted must be re-staged (the serving layer's
    /// supervisor re-materializes them from session records). The
    /// default refuses — not every transport can heal.
    fn recover(&self, w: usize) -> Result<(), TransportError> {
        Err(TransportError::Fatal(format!(
            "worker {w}: this transport cannot recover workers"
        )))
    }

    /// Chaos hook: corrupt the wire framing toward worker `w` (an
    /// oversized length prefix). Returns `false` when the transport has
    /// no frames to corrupt (in-process channels).
    fn inject_corrupt_frame(&self, w: usize) -> bool {
        let _ = w;
        false
    }

    /// Drain in-flight work, stop the workers, and return per-worker
    /// processed-request counts.
    fn shutdown(self: Box<Self>) -> Vec<u64>;
}

fn pool_err(e: PoolError) -> TransportError {
    match e {
        PoolError::QueueFull(w) => TransportError::Retryable(format!("worker {w}: queue full")),
        PoolError::WorkerGone(w) => TransportError::Fatal(format!("worker {w}: disconnected")),
    }
}

/// The original in-process transport: worker threads behind bounded
/// `mpsc` channels ([`WorkerPool`]).
pub struct ChannelTransport {
    pool: WorkerPool,
}

impl ChannelTransport {
    pub fn spawn(workers: usize, queue_depth: usize, kernel: KernelConfig) -> ChannelTransport {
        ChannelTransport { pool: WorkerPool::spawn_with_kernel(workers, queue_depth, kernel) }
    }
}

impl ShardTransport for ChannelTransport {
    fn name(&self) -> &'static str {
        "channels"
    }

    fn workers(&self) -> usize {
        self.pool.len()
    }

    fn request(&self, w: usize, req: ShardRequest) -> Result<ReplyTicket, TransportError> {
        let (tx, rx) = channel();
        self.pool.send(w, Job::Request { req, reply: tx }).map_err(pool_err)?;
        Ok(ReplyTicket::new(rx, w))
    }

    fn try_request(&self, w: usize, req: ShardRequest) -> Result<ReplyTicket, TransportError> {
        let (tx, rx) = channel();
        self.pool.try_send(w, Job::Request { req, reply: tx }).map_err(pool_err)?;
        Ok(ReplyTicket::new(rx, w))
    }

    fn flush(&self) -> Result<(), TransportError> {
        self.pool.flush().map_err(pool_err)
    }

    fn recover(&self, w: usize) -> Result<(), TransportError> {
        self.pool.respawn(w);
        Ok(())
    }

    fn shutdown(self: Box<Self>) -> Vec<u64> {
        self.pool.shutdown()
    }
}

// ---------------------------------------------------------------------
// Unix-domain-socket transport: length-prefixed frames, one socket per
// worker, request-id demux on a per-connection reader thread.
// ---------------------------------------------------------------------

#[cfg(unix)]
pub use socket::SocketTransport;

#[cfg(unix)]
mod socket {
    use super::*;
    use std::io::{Read, Write};
    use std::os::unix::net::{UnixListener, UnixStream};
    use std::path::PathBuf;
    use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
    use std::sync::{Arc, Mutex};
    use std::thread::JoinHandle;

    // ---- wire codec (little-endian, bit-exact f64 round trip) ----

    fn put_u64(buf: &mut Vec<u8>, v: u64) {
        buf.extend_from_slice(&v.to_le_bytes());
    }

    fn put_f64(buf: &mut Vec<u8>, v: f64) {
        buf.extend_from_slice(&v.to_le_bytes());
    }

    fn put_mat(buf: &mut Vec<u8>, m: &Mat) {
        put_u64(buf, m.rows() as u64);
        put_u64(buf, m.cols() as u64);
        for &v in m.as_slice() {
            put_f64(buf, v);
        }
    }

    fn put_str(buf: &mut Vec<u8>, s: &str) {
        put_u64(buf, s.len() as u64);
        buf.extend_from_slice(s.as_bytes());
    }

    struct Cursor<'a> {
        buf: &'a [u8],
        pos: usize,
    }

    impl<'a> Cursor<'a> {
        fn new(buf: &'a [u8]) -> Cursor<'a> {
            Cursor { buf, pos: 0 }
        }

        fn take(&mut self, n: usize) -> Result<&'a [u8], String> {
            if self.pos + n > self.buf.len() {
                return Err("truncated frame".into());
            }
            let out = &self.buf[self.pos..self.pos + n];
            self.pos += n;
            Ok(out)
        }

        fn u8(&mut self) -> Result<u8, String> {
            Ok(self.take(1)?[0])
        }

        fn u64(&mut self) -> Result<u64, String> {
            let b = self.take(8)?;
            Ok(u64::from_le_bytes(b.try_into().unwrap()))
        }

        fn f64(&mut self) -> Result<f64, String> {
            let b = self.take(8)?;
            Ok(f64::from_le_bytes(b.try_into().unwrap()))
        }

        fn mat(&mut self) -> Result<Mat, String> {
            let rows = self.u64()? as usize;
            let cols = self.u64()? as usize;
            let mut data = Vec::with_capacity(rows * cols);
            for _ in 0..rows * cols {
                data.push(self.f64()?);
            }
            Ok(Mat::from_vec(rows, cols, data))
        }

        fn string(&mut self) -> Result<String, String> {
            let len = self.u64()? as usize;
            let b = self.take(len)?;
            String::from_utf8(b.to_vec()).map_err(|_| "non-utf8 string".to_string())
        }
    }

    const OP_SET_SHARD: u8 = 0;
    const OP_DROP_SHARD: u8 = 1;
    const OP_GRAM: u8 = 2;
    const OP_MATVEC_MANY: u8 = 3;
    const OP_APPLY_MANY: u8 = 4;
    const OP_UPDATE_ROWS: u8 = 5;
    const OP_STALL: u8 = 6;
    const OP_PING: u8 = 7;
    const OP_DIE: u8 = 8;
    /// Transport-internal: drain and stop, replying the processed count.
    const OP_SHUTDOWN: u8 = 9;

    const TAG_ACK: u8 = 0;
    const TAG_MAT: u8 = 1;
    const TAG_ERR: u8 = 2;
    const TAG_COUNT: u8 = 3;

    fn encode_request(id: u64, req: &ShardRequest) -> Vec<u8> {
        let mut b = Vec::new();
        put_u64(&mut b, id);
        match req {
            ShardRequest::SetShard { sid, shard } => {
                b.push(OP_SET_SHARD);
                put_u64(&mut b, *sid);
                put_mat(&mut b, shard);
            }
            ShardRequest::DropShard { sid } => {
                b.push(OP_DROP_SHARD);
                put_u64(&mut b, *sid);
            }
            ShardRequest::Gram { sid } => {
                b.push(OP_GRAM);
                put_u64(&mut b, *sid);
            }
            ShardRequest::MatvecMany { sid, v_k } => {
                b.push(OP_MATVEC_MANY);
                put_u64(&mut b, *sid);
                put_mat(&mut b, v_k);
            }
            ShardRequest::ApplyMany { sid, z, v_k, lambda } => {
                b.push(OP_APPLY_MANY);
                put_u64(&mut b, *sid);
                put_mat(&mut b, z);
                put_mat(&mut b, v_k);
                put_f64(&mut b, *lambda);
            }
            ShardRequest::UpdateRows { sid, removed, added_k } => {
                b.push(OP_UPDATE_ROWS);
                put_u64(&mut b, *sid);
                put_u64(&mut b, removed.len() as u64);
                for &r in removed {
                    put_u64(&mut b, r as u64);
                }
                put_mat(&mut b, added_k);
            }
            ShardRequest::Stall { ms } => {
                b.push(OP_STALL);
                put_u64(&mut b, *ms);
            }
            ShardRequest::Ping => b.push(OP_PING),
            ShardRequest::Die => b.push(OP_DIE),
        }
        b
    }

    /// `None` = the transport-internal shutdown frame.
    fn decode_request(body: &[u8]) -> Result<(u64, Option<ShardRequest>), String> {
        let mut c = Cursor::new(body);
        let id = c.u64()?;
        let op = c.u8()?;
        let req = match op {
            OP_SET_SHARD => {
                ShardRequest::SetShard { sid: c.u64()?, shard: c.mat()? }
            }
            OP_DROP_SHARD => ShardRequest::DropShard { sid: c.u64()? },
            OP_GRAM => ShardRequest::Gram { sid: c.u64()? },
            OP_MATVEC_MANY => ShardRequest::MatvecMany { sid: c.u64()?, v_k: c.mat()? },
            OP_APPLY_MANY => {
                let sid = c.u64()?;
                let z = c.mat()?;
                let v_k = c.mat()?;
                let lambda = c.f64()?;
                ShardRequest::ApplyMany { sid, z, v_k, lambda }
            }
            OP_UPDATE_ROWS => {
                let sid = c.u64()?;
                let len = c.u64()? as usize;
                let mut removed = Vec::with_capacity(len);
                for _ in 0..len {
                    removed.push(c.u64()? as usize);
                }
                ShardRequest::UpdateRows { sid, removed, added_k: c.mat()? }
            }
            OP_STALL => ShardRequest::Stall { ms: c.u64()? },
            OP_PING => ShardRequest::Ping,
            OP_DIE => ShardRequest::Die,
            OP_SHUTDOWN => return Ok((id, None)),
            other => return Err(format!("unknown opcode {other}")),
        };
        Ok((id, Some(req)))
    }

    fn encode_response(id: u64, resp: &ShardResponse) -> Vec<u8> {
        let mut b = Vec::new();
        put_u64(&mut b, id);
        match resp {
            ShardResponse::Ack => b.push(TAG_ACK),
            ShardResponse::Mat(m) => {
                b.push(TAG_MAT);
                put_mat(&mut b, m);
            }
            ShardResponse::Err(e) => {
                b.push(TAG_ERR);
                put_str(&mut b, e);
            }
            ShardResponse::Count(n) => {
                b.push(TAG_COUNT);
                put_u64(&mut b, *n);
            }
        }
        b
    }

    fn decode_response(body: &[u8]) -> Result<(u64, ShardResponse), String> {
        let mut c = Cursor::new(body);
        let id = c.u64()?;
        let resp = match c.u8()? {
            TAG_ACK => ShardResponse::Ack,
            TAG_MAT => ShardResponse::Mat(c.mat()?),
            TAG_ERR => ShardResponse::Err(c.string()?),
            TAG_COUNT => ShardResponse::Count(c.u64()?),
            other => return Err(format!("unknown response tag {other}")),
        };
        Ok((id, resp))
    }

    /// Frames larger than this are a protocol error, not a real
    /// payload. Checked on the advertised length **before** allocating
    /// the body (an attacker-controlled u32 must never size a `Vec`)
    /// and on the leader's encoded requests before any bytes move
    /// (typed [`TransportError::FrameTooLarge`]).
    const MAX_FRAME: u32 = 1 << 30;

    /// Read-timeout poll interval: streams wake this often so a reader
    /// blocked on a half-written frame can notice the stall instead of
    /// hanging in `read` forever.
    const READ_POLL: Duration = Duration::from_millis(100);

    /// Once a frame has started arriving, the peer gets this long to
    /// finish it; an idle stream (no frame in progress) may wait
    /// forever. This is what keeps a half-written frame from wedging
    /// the demux thread.
    const FRAME_STALL_MS: u128 = 2_000;

    /// Why a frame read failed — the worker loop and the demux reader
    /// react differently to corruption vs a plain closed connection.
    #[derive(Debug)]
    enum FrameError {
        /// The advertised length exceeds [`MAX_FRAME`]: the framing is
        /// corrupt and the connection cannot be resynchronized.
        TooLarge { len: u32 },
        /// A frame started arriving but stalled mid-body past
        /// [`FRAME_STALL_MS`] — the peer is wedged, not idle.
        Stalled,
        /// Closed connection / genuine I/O failure. The payload is
        /// diagnostic only (Debug in tests) — both read loops react to
        /// any `Io` by dropping the connection.
        Io(#[allow(dead_code)] std::io::Error),
    }

    fn write_frame(s: &mut UnixStream, body: &[u8]) -> std::io::Result<()> {
        s.write_all(&(body.len() as u32).to_le_bytes())?;
        s.write_all(body)
    }

    /// Fill `buf` exactly, surviving partial reads, EINTR and the
    /// [`READ_POLL`] timeouts. `started` tracks whether any byte of the
    /// current frame has arrived: while `false` the stream is idle
    /// between frames and may block indefinitely; once `true` the stall
    /// clock runs.
    fn read_full(
        s: &mut UnixStream,
        buf: &mut [u8],
        started: &mut bool,
    ) -> Result<(), FrameError> {
        let mut filled = 0;
        let mut stalled_since: Option<std::time::Instant> = None;
        while filled < buf.len() {
            match s.read(&mut buf[filled..]) {
                Ok(0) => {
                    return Err(FrameError::Io(std::io::ErrorKind::UnexpectedEof.into()));
                }
                Ok(n) => {
                    filled += n;
                    *started = true;
                    stalled_since = None;
                }
                // EINTR: the syscall was interrupted by a signal —
                // retry immediately, no data was consumed.
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock
                        || e.kind() == std::io::ErrorKind::TimedOut =>
                {
                    if !*started {
                        continue; // idle between frames: keep waiting
                    }
                    let since = stalled_since.get_or_insert_with(std::time::Instant::now);
                    if since.elapsed().as_millis() >= FRAME_STALL_MS {
                        return Err(FrameError::Stalled);
                    }
                }
                Err(e) => return Err(FrameError::Io(e)),
            }
        }
        Ok(())
    }

    fn read_frame(s: &mut UnixStream) -> Result<Vec<u8>, FrameError> {
        let mut started = false;
        let mut len = [0u8; 4];
        read_full(s, &mut len, &mut started)?;
        let len = u32::from_le_bytes(len);
        if len > MAX_FRAME {
            return Err(FrameError::TooLarge { len });
        }
        let mut body = vec![0u8; len as usize];
        read_full(s, &mut body, &mut started)?;
        Ok(body)
    }

    /// Remote side: serve one connection until shutdown/crash/EOF.
    /// Returns the processed-request count (every received frame,
    /// including the shutdown frame — mirroring the channel pool's
    /// accounting).
    fn socket_worker(listener: UnixListener, kernel: KernelConfig) -> u64 {
        let Ok((mut stream, _)) = listener.accept() else {
            return 0;
        };
        // Poll-style reads so a half-written frame trips the stall
        // guard instead of parking this thread in `read` forever.
        let _ = stream.set_read_timeout(Some(READ_POLL));
        let mut shards: HashMap<u64, Mat> = HashMap::new();
        let mut processed: u64 = 0;
        loop {
            let body = match read_frame(&mut stream) {
                Ok(b) => b,
                Err(FrameError::TooLarge { len }) => {
                    // Corrupt framing cannot be resynchronized: report
                    // (id u64::MAX is never a live request id) and drop
                    // the connection.
                    let resp = ShardResponse::Err(format!(
                        "frame of {len} bytes exceeds the {MAX_FRAME}-byte limit"
                    ));
                    let _ = write_frame(&mut stream, &encode_response(u64::MAX, &resp));
                    break;
                }
                Err(_) => break,
            };
            processed += 1;
            let (id, req) = match decode_request(&body) {
                Ok(x) => x,
                Err(e) => {
                    // Protocol error: answer, then drop the connection —
                    // framing can no longer be trusted.
                    let _ = write_frame(&mut stream, &encode_response(0, &ShardResponse::Err(e)));
                    break;
                }
            };
            match req {
                None => {
                    // Shutdown frame: reply the counter, then exit.
                    let resp = ShardResponse::Count(processed);
                    let _ = write_frame(&mut stream, &encode_response(id, &resp));
                    break;
                }
                Some(ShardRequest::Die) => break, // crash: no reply
                Some(r) => {
                    let resp = execute_request(&mut shards, r, kernel);
                    let _ = write_frame(&mut stream, &encode_response(id, &resp));
                }
            }
        }
        processed
    }

    /// Request-id → reply-sender demux table for one connection.
    type PendingMap = Arc<Mutex<HashMap<u64, Sender<ShardResponse>>>>;

    struct SocketLink {
        write: Mutex<UnixStream>,
        pending: PendingMap,
        next_id: AtomicU64,
        dead: Arc<AtomicBool>,
        reader: Option<JoinHandle<()>>,
        worker: Option<JoinHandle<u64>>,
        path: PathBuf,
    }

    /// Length-prefixed Unix-domain-socket transport. Worker threads in
    /// this build stand in for genuinely remote processes: everything
    /// crossing the leader/worker boundary goes through the wire codec,
    /// so pointing the connector at an external `dngd` worker process
    /// is a deployment change, not a code change.
    ///
    /// Each link sits behind an `RwLock` so [`ShardTransport::recover`]
    /// can rebind + reconnect a dead worker in place while live traffic
    /// to the other workers keeps flowing.
    pub struct SocketTransport {
        links: Vec<std::sync::RwLock<SocketLink>>,
        dir: PathBuf,
        kernel: KernelConfig,
        /// Processed counts of replaced incarnations, folded into the
        /// per-slot totals at shutdown (mirrors the channel pool).
        retired: Mutex<Vec<u64>>,
    }

    impl SocketTransport {
        /// Bind one socket per worker under a unique temp directory and
        /// spawn the serving threads.
        pub fn spawn(workers: usize, kernel: KernelConfig) -> Result<SocketTransport, TransportError> {
            assert!(workers > 0);
            static COUNTER: AtomicU64 = AtomicU64::new(0);
            let dir = std::env::temp_dir().join(format!(
                "dngd-sock-{}-{}",
                std::process::id(),
                COUNTER.fetch_add(1, Ordering::Relaxed)
            ));
            std::fs::create_dir_all(&dir)
                .map_err(|e| TransportError::Fatal(format!("create socket dir: {e}")))?;
            let mut links = Vec::with_capacity(workers);
            for w in 0..workers {
                links.push(std::sync::RwLock::new(Self::open_link(&dir, w, kernel)?));
            }
            Ok(SocketTransport { links, dir, kernel, retired: Mutex::new(vec![0; workers]) })
        }

        /// Bind worker `w`'s socket (replacing any stale file from a
        /// dead incarnation), spawn its serving thread, connect, and
        /// start the demux reader.
        fn open_link(
            dir: &std::path::Path,
            w: usize,
            kernel: KernelConfig,
        ) -> Result<SocketLink, TransportError> {
            let path = dir.join(format!("worker{w}.sock"));
            let _ = std::fs::remove_file(&path);
            let listener = UnixListener::bind(&path)
                .map_err(|e| TransportError::Fatal(format!("bind {path:?}: {e}")))?;
            let worker = std::thread::Builder::new()
                .name(format!("dngd-sock-worker-{w}"))
                .spawn(move || socket_worker(listener, kernel))
                .map_err(|e| TransportError::Fatal(format!("spawn worker: {e}")))?;
            let stream = UnixStream::connect(&path)
                .map_err(|e| TransportError::Fatal(format!("connect {path:?}: {e}")))?;
            let mut rstream = stream
                .try_clone()
                .map_err(|e| TransportError::Fatal(format!("clone stream: {e}")))?;
            let _ = rstream.set_read_timeout(Some(READ_POLL));
            let pending: PendingMap = Arc::new(Mutex::new(HashMap::new()));
            let dead = Arc::new(AtomicBool::new(false));
            let (p2, d2) = (pending.clone(), dead.clone());
            let reader = std::thread::Builder::new()
                .name(format!("dngd-sock-reader-{w}"))
                .spawn(move || {
                    loop {
                        let body = match read_frame(&mut rstream) {
                            Ok(b) => b,
                            Err(_) => break,
                        };
                        let Ok((id, resp)) = decode_response(&body) else { break };
                        if let Some(tx) = p2.lock().unwrap().remove(&id) {
                            let _ = tx.send(resp);
                        }
                    }
                    // Connection down: mark dead and fail all
                    // in-flight tickets (their senders drop here).
                    d2.store(true, Ordering::Release);
                    p2.lock().unwrap().clear();
                })
                .map_err(|e| TransportError::Fatal(format!("spawn reader: {e}")))?;
            Ok(SocketLink {
                write: Mutex::new(stream),
                pending,
                next_id: AtomicU64::new(0),
                dead,
                reader: Some(reader),
                worker: Some(worker),
                path,
            })
        }

        fn send_frame(&self, w: usize, req: &ShardRequest) -> Result<ReplyTicket, TransportError> {
            let link = self.links[w].read().unwrap_or_else(std::sync::PoisonError::into_inner);
            if link.dead.load(Ordering::Acquire) {
                return Err(TransportError::Fatal(format!("worker {w}: connection closed")));
            }
            let id = link.next_id.fetch_add(1, Ordering::Relaxed);
            let frame = encode_request(id, req);
            if frame.len() as u64 > MAX_FRAME as u64 {
                return Err(TransportError::FrameTooLarge {
                    len: frame.len() as u64,
                    max: MAX_FRAME as u64,
                });
            }
            let (tx, rx) = channel();
            link.pending.lock().unwrap().insert(id, tx);
            let res = {
                let mut s = link.write.lock().unwrap();
                write_frame(&mut s, &frame)
            };
            if let Err(e) = res {
                link.pending.lock().unwrap().remove(&id);
                link.dead.store(true, Ordering::Release);
                return Err(TransportError::Fatal(format!("worker {w}: write failed: {e}")));
            }
            Ok(ReplyTicket::new(rx, w))
        }
    }

    impl ShardTransport for SocketTransport {
        fn name(&self) -> &'static str {
            "socket"
        }

        fn workers(&self) -> usize {
            self.links.len()
        }

        fn request(&self, w: usize, req: ShardRequest) -> Result<ReplyTicket, TransportError> {
            self.send_frame(w, &req)
        }

        fn try_request(&self, w: usize, req: ShardRequest) -> Result<ReplyTicket, TransportError> {
            // Socket back-pressure is the kernel's socket buffer; there
            // is no app-level bounded queue to observe, so try == send.
            self.send_frame(w, &req)
        }

        fn flush(&self) -> Result<(), TransportError> {
            // Frames are served FIFO per connection, so a Ping round
            // trip on every worker is a full barrier.
            let mut tickets = Vec::with_capacity(self.links.len());
            for w in 0..self.links.len() {
                tickets.push(self.send_frame(w, &ShardRequest::Ping)?);
            }
            for t in tickets {
                t.wait()?;
            }
            Ok(())
        }

        fn recover(&self, w: usize) -> Result<(), TransportError> {
            // Open the replacement first: if the rebind fails the old
            // (dead) link stays in place and the error is reported.
            let fresh = Self::open_link(&self.dir, w, self.kernel)?;
            let mut old = {
                let mut slot =
                    self.links[w].write().unwrap_or_else(std::sync::PoisonError::into_inner);
                std::mem::replace(&mut *slot, fresh)
            };
            // Tear the old incarnation down: closing both halves makes
            // its worker (if somehow alive) and reader see EOF and
            // exit, then fold its processed count into the slot total.
            if let Ok(s) = old.write.lock() {
                let _ = s.shutdown(std::net::Shutdown::Both);
            }
            if let Some(j) = old.worker.take() {
                let count = j.join().unwrap_or(0);
                self.retired.lock().unwrap_or_else(std::sync::PoisonError::into_inner)[w] +=
                    count;
            }
            if let Some(r) = old.reader.take() {
                let _ = r.join(); // clears `pending`, failing in-flight tickets
            }
            Ok(())
        }

        fn inject_corrupt_frame(&self, w: usize) -> bool {
            // A raw length prefix claiming a 4 GiB body, no payload:
            // the worker's framing guard rejects it and drops the
            // connection — the frame never resynchronizes.
            let link = self.links[w].read().unwrap_or_else(std::sync::PoisonError::into_inner);
            let mut s = link.write.lock().unwrap();
            let _ = s.write_all(&u32::MAX.to_le_bytes());
            true
        }

        fn shutdown(mut self: Box<Self>) -> Vec<u64> {
            let mut counts = Vec::with_capacity(self.links.len());
            for slot in &self.links {
                // Best-effort shutdown frame (no pending registration —
                // the count comes back via the thread join, which also
                // covers workers that already died).
                let link = slot.read().unwrap_or_else(std::sync::PoisonError::into_inner);
                let mut frame = Vec::new();
                put_u64(&mut frame, u64::MAX);
                frame.push(OP_SHUTDOWN);
                let _ = {
                    let mut s = link.write.lock().unwrap();
                    write_frame(&mut s, &frame)
                };
            }
            for slot in &mut self.links {
                let link = slot.get_mut().unwrap_or_else(std::sync::PoisonError::into_inner);
                counts.push(link.worker.take().map(|j| j.join().unwrap_or(0)).unwrap_or(0));
                if let Some(r) = link.reader.take() {
                    let _ = r.join();
                }
                let _ = std::fs::remove_file(&link.path);
            }
            let retired = self.retired.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
            for (w, &c) in retired.iter().enumerate() {
                counts[w] += c;
            }
            drop(retired);
            let _ = std::fs::remove_dir(&self.dir);
            counts
        }
    }

    impl Drop for SocketTransport {
        fn drop(&mut self) {
            // Shutdown not called (e.g. panic unwind): close write
            // halves so worker threads see EOF and exit; detach joins.
            for slot in &mut self.links {
                let link = slot.get_mut().unwrap_or_else(std::sync::PoisonError::into_inner);
                if let Ok(s) = link.write.lock() {
                    let _ = s.shutdown(std::net::Shutdown::Both);
                }
                let _ = std::fs::remove_file(&link.path);
            }
            let _ = std::fs::remove_dir(&self.dir);
        }
    }

    #[cfg(test)]
    mod tests {
        use super::*;
        use crate::data::rng::Rng;

        #[test]
        fn codec_round_trips_requests_bit_exactly() {
            let mut rng = Rng::seed_from(700);
            let m = Mat::randn(3, 5, &mut rng);
            let reqs = vec![
                ShardRequest::SetShard { sid: 7, shard: m.clone() },
                ShardRequest::DropShard { sid: 7 },
                ShardRequest::Gram { sid: 1 },
                ShardRequest::MatvecMany { sid: 2, v_k: m.clone() },
                ShardRequest::ApplyMany {
                    sid: 3,
                    z: m.clone(),
                    v_k: m.clone(),
                    lambda: 0.125,
                },
                ShardRequest::UpdateRows { sid: 4, removed: vec![0, 2], added_k: m.clone() },
                ShardRequest::Stall { ms: 9 },
                ShardRequest::Ping,
                ShardRequest::Die,
            ];
            for (i, req) in reqs.iter().enumerate() {
                let body = encode_request(i as u64, req);
                let (id, back) = decode_request(&body).unwrap();
                assert_eq!(id, i as u64);
                let back = back.expect("not a shutdown frame");
                // Compare via re-encoding: Mat payloads must round-trip
                // bit-exactly (f64 ↔ le_bytes is lossless).
                assert_eq!(encode_request(i as u64, &back), body);
            }
        }

        #[test]
        fn codec_round_trips_responses() {
            let mut rng = Rng::seed_from(701);
            let m = Mat::randn(2, 4, &mut rng);
            for resp in [
                ShardResponse::Ack,
                ShardResponse::Mat(m),
                ShardResponse::Err("boom".into()),
                ShardResponse::Count(42),
            ] {
                let body = encode_response(9, &resp);
                let (id, back) = decode_response(&body).unwrap();
                assert_eq!(id, 9);
                assert_eq!(back, resp);
            }
        }

        #[test]
        fn oversized_length_prefix_is_rejected_before_allocation() {
            let (mut a, mut b) = UnixStream::pair().unwrap();
            b.set_read_timeout(Some(READ_POLL)).unwrap();
            a.write_all(&u32::MAX.to_le_bytes()).unwrap();
            match read_frame(&mut b) {
                Err(FrameError::TooLarge { len }) => assert_eq!(len, u32::MAX),
                other => panic!("expected TooLarge, got {other:?}"),
            }
        }

        #[test]
        fn partial_reads_reassemble_the_frame() {
            let (mut a, mut b) = UnixStream::pair().unwrap();
            b.set_read_timeout(Some(READ_POLL)).unwrap();
            let h = std::thread::spawn(move || {
                a.write_all(&64u32.to_le_bytes()).unwrap();
                // Dribble the body in 7-byte chunks with gaps: the
                // reader must reassemble across short reads.
                for chunk in [7u8; 64].chunks(7) {
                    a.write_all(chunk).unwrap();
                    std::thread::sleep(Duration::from_millis(1));
                }
            });
            let got = read_frame(&mut b).unwrap();
            assert_eq!(got, vec![7u8; 64]);
            h.join().unwrap();
        }

        #[test]
        fn half_written_frame_stalls_out_instead_of_hanging() {
            let (mut a, mut b) = UnixStream::pair().unwrap();
            b.set_read_timeout(Some(READ_POLL)).unwrap();
            // Advertise a 100-byte body but deliver only 10 bytes and
            // keep the connection open: the stall guard must fire.
            a.write_all(&100u32.to_le_bytes()).unwrap();
            a.write_all(&[0u8; 10]).unwrap();
            let t0 = std::time::Instant::now();
            let res = read_frame(&mut b);
            assert!(matches!(res, Err(FrameError::Stalled)), "{res:?}");
            let waited = t0.elapsed().as_millis();
            assert!(
                waited >= FRAME_STALL_MS && waited < 4 * FRAME_STALL_MS,
                "stall guard fired after {waited}ms"
            );
            drop(a);
        }

        #[test]
        fn oversized_request_is_refused_with_typed_frame_too_large() {
            // The leader-side guard (send_frame) refuses before any
            // bytes move; exercised here against the cap constant
            // directly — a real >1 GiB payload is not test material.
            let e = TransportError::FrameTooLarge { len: MAX_FRAME as u64 + 1, max: MAX_FRAME as u64 };
            assert!(!e.is_retryable());
            assert!(e.to_string().contains("exceeds"), "{e}");
        }

        #[test]
        fn corrupt_frame_is_fatal_then_recover_heals() {
            let mut rng = Rng::seed_from(708);
            let t = SocketTransport::spawn(1, KernelConfig::serial()).unwrap();
            let s = Mat::randn(3, 4, &mut rng);
            t.request(0, ShardRequest::SetShard { sid: 5, shard: s })
                .unwrap()
                .wait()
                .unwrap();
            assert!(t.inject_corrupt_frame(0));
            // The poisoned framing drops the connection: in-flight and
            // future requests surface fatally (never hang).
            let mut saw_fatal = false;
            for _ in 0..50 {
                match t.request(0, ShardRequest::Ping) {
                    Err(TransportError::Fatal(_)) => {
                        saw_fatal = true;
                        break;
                    }
                    Err(_) => {}
                    Ok(ticket) => {
                        if matches!(ticket.wait(), Err(TransportError::Fatal(_))) {
                            saw_fatal = true;
                            break;
                        }
                    }
                }
            }
            assert!(saw_fatal, "corrupted link never surfaced as fatal");
            t.recover(0).unwrap();
            let ok = t.request(0, ShardRequest::Ping).unwrap().wait().unwrap();
            assert_eq!(ok, ShardResponse::Ack);
            // The revived worker is empty: the old session must be
            // re-staged, not silently resurrected.
            let gone = t.request(0, ShardRequest::Gram { sid: 5 }).unwrap().wait().unwrap();
            assert!(matches!(gone, ShardResponse::Err(_)), "{gone:?}");
            Box::new(t).shutdown();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;

    fn transports() -> Vec<Box<dyn ShardTransport>> {
        let mut v: Vec<Box<dyn ShardTransport>> =
            vec![Box::new(ChannelTransport::spawn(2, 4, KernelConfig::serial()))];
        #[cfg(unix)]
        v.push(Box::new(SocketTransport::spawn(2, KernelConfig::serial()).unwrap()));
        v
    }

    #[test]
    fn round_trip_gram_on_both_transports() {
        let mut rng = Rng::seed_from(702);
        let s = Mat::randn(4, 6, &mut rng);
        let want = crate::linalg::gemm::syrk(&s, 0.0);
        for t in transports() {
            let ack = t.request(0, ShardRequest::SetShard { sid: 1, shard: s.clone() }).unwrap();
            assert_eq!(ack.wait().unwrap(), ShardResponse::Ack);
            let got = t.request(0, ShardRequest::Gram { sid: 1 }).unwrap().wait().unwrap();
            match got {
                ShardResponse::Mat(g) => assert_eq!(g, want, "{}", t.name()),
                other => panic!("{}: unexpected response {other:?}", t.name()),
            }
            let counts = t.shutdown();
            assert_eq!(counts.len(), 2);
        }
    }

    #[test]
    fn missing_session_is_a_semantic_error_not_a_hang() {
        for t in transports() {
            let resp = t.request(0, ShardRequest::Gram { sid: 99 }).unwrap().wait().unwrap();
            assert!(matches!(resp, ShardResponse::Err(_)), "{}", t.name());
            t.shutdown();
        }
    }

    #[test]
    fn die_fails_in_flight_and_future_requests_fatally() {
        for t in transports() {
            // The Die itself never replies; its ticket must error, not hang.
            let dead = t.request(0, ShardRequest::Die).unwrap();
            assert!(matches!(dead.wait(), Err(TransportError::Fatal(_))), "{}", t.name());
            // Subsequent requests on the dead worker fail fatally too
            // (possibly after one buffered write on the socket path).
            let mut saw_fatal = false;
            for _ in 0..4 {
                match t.request(0, ShardRequest::Ping) {
                    Err(TransportError::Fatal(_)) => {
                        saw_fatal = true;
                        break;
                    }
                    Err(TransportError::Retryable(_)) => {}
                    Ok(ticket) => {
                        if matches!(ticket.wait(), Err(TransportError::Fatal(_))) {
                            saw_fatal = true;
                            break;
                        }
                    }
                }
            }
            assert!(saw_fatal, "{}: dead worker never surfaced as fatal", t.name());
            // The *other* worker is untouched.
            let ok = t.request(1, ShardRequest::Ping).unwrap().wait().unwrap();
            assert_eq!(ok, ShardResponse::Ack, "{}", t.name());
            t.shutdown();
        }
    }

    #[test]
    fn probe_and_recover_revive_a_killed_worker() {
        for t in transports() {
            assert!(t.probe(0, Duration::from_millis(500)), "{}: live worker", t.name());
            let _ = t.request(0, ShardRequest::Die).unwrap();
            // The death takes a moment to become observable.
            let mut dead = false;
            for _ in 0..200 {
                if !t.probe(0, Duration::from_millis(50)) {
                    dead = true;
                    break;
                }
                std::thread::sleep(Duration::from_millis(2));
            }
            assert!(dead, "{}: killed worker kept answering probes", t.name());
            t.recover(0).unwrap();
            assert!(
                t.probe(0, Duration::from_millis(500)),
                "{}: recovered worker must answer pings",
                t.name()
            );
            // The revived worker starts empty — sessions need re-staging.
            let resp = t.request(0, ShardRequest::Gram { sid: 1 }).unwrap().wait().unwrap();
            assert!(matches!(resp, ShardResponse::Err(_)), "{}", t.name());
            // The untouched worker was never disturbed.
            let ok = t.request(1, ShardRequest::Ping).unwrap().wait().unwrap();
            assert_eq!(ok, ShardResponse::Ack, "{}", t.name());
            t.shutdown();
        }
    }

    #[test]
    fn flush_is_a_fifo_barrier() {
        for t in transports() {
            let slow = t.request(0, ShardRequest::Stall { ms: 30 }).unwrap();
            let t0 = std::time::Instant::now();
            t.flush().unwrap();
            assert!(
                t0.elapsed() >= std::time::Duration::from_millis(20),
                "{}: flush returned before the stalled request drained",
                t.name()
            );
            assert_eq!(slow.wait().unwrap(), ShardResponse::Ack);
            t.shutdown();
        }
    }
}
