//! Bounded request queue + cross-tenant RHS coalescing (PR 7).
//!
//! Tenants enqueue single-RHS solve requests and window rotations; the
//! dispatcher drains the queue once per tick and **coalesces** solves
//! that target the same session at the same λ into one `solve_many`
//! panel — the same per-session amortization PR 2/PR 5 exploit, applied
//! *across* tenants. Admission is reject-with-retry-after, never OOM or
//! unbounded queueing: a full queue surfaces [`ServeError::Overloaded`]
//! at submit time, and the memory model (`cost.rs`) gates session
//! admission in `serve/server.rs` with [`ServeError::OverBudget`].

use crate::linalg::Mat;
use crate::solver::SolveError;
use std::collections::{HashMap, VecDeque};
use std::sync::mpsc::Sender;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// Serving-layer failures handed back to tenants. Retryable variants
/// carry an explicit back-off hint instead of letting the server fall
/// over — see [`ServeError::is_retryable`].
#[derive(Debug, Clone, PartialEq)]
pub enum ServeError {
    /// The dispatch queue is at `serve.queue_depth`; resubmit after the
    /// hinted back-off (≈ one dispatch tick).
    Overloaded { retry_after_ms: u64 },
    /// Admitting the session would exceed `serve.budget_gb` under the
    /// `cost.rs` memory model; retry after other tenants release
    /// sessions.
    OverBudget { required_bytes: u64, budget_bytes: u64, retry_after_ms: u64 },
    /// All `serve.tenants` connection slots are taken.
    TenantLimit { tenants: usize },
    /// No live session with this id (never opened, or closed).
    UnknownSession(u64),
    /// The underlying solve failed; inspect the inner error (a
    /// [`SolveError::Backend`] may itself be retryable).
    Solver(SolveError),
    /// The per-request deadline (`serve.deadline_ms`) elapsed before an
    /// answer — including any recovery attempts. Carries the partial-
    /// progress stats: how long the request was in flight and how many
    /// retries were burned. Not retryable as-is (the *caller* decides
    /// whether a fresh request with a fresh deadline is worth it).
    DeadlineExceeded { elapsed_ms: u64, retries: u64 },
    /// The server is shutting down.
    ShuttingDown,
}

impl ServeError {
    /// Whether resubmitting the same request later can succeed.
    pub fn is_retryable(&self) -> bool {
        match self {
            ServeError::Overloaded { .. }
            | ServeError::OverBudget { .. }
            | ServeError::TenantLimit { .. } => true,
            ServeError::Solver(SolveError::Backend { retryable, .. }) => *retryable,
            ServeError::UnknownSession(_)
            | ServeError::Solver(_)
            | ServeError::DeadlineExceeded { .. }
            | ServeError::ShuttingDown => false,
        }
    }

    /// The server's explicit back-off hint, when it gave one.
    /// [`crate::serve::Client`] blocking calls honor this by sleeping
    /// the hinted interval (bounded by the request deadline) before
    /// resubmitting.
    pub fn retry_after_ms(&self) -> Option<u64> {
        match self {
            ServeError::Overloaded { retry_after_ms }
            | ServeError::OverBudget { retry_after_ms, .. } => Some(*retry_after_ms),
            _ => None,
        }
    }
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::Overloaded { retry_after_ms } => {
                write!(f, "server overloaded; retry after {retry_after_ms} ms")
            }
            ServeError::OverBudget { required_bytes, budget_bytes, retry_after_ms } => write!(
                f,
                "session needs {required_bytes} B but only {budget_bytes} B budget remains; \
                 retry after {retry_after_ms} ms"
            ),
            ServeError::TenantLimit { tenants } => {
                write!(f, "all {tenants} tenant slots in use")
            }
            ServeError::UnknownSession(sid) => write!(f, "unknown session {sid}"),
            ServeError::Solver(e) => write!(f, "solve failed: {e}"),
            ServeError::DeadlineExceeded { elapsed_ms, retries } => write!(
                f,
                "deadline exceeded after {elapsed_ms} ms ({retries} retries)"
            ),
            ServeError::ShuttingDown => write!(f, "server shutting down"),
        }
    }
}

impl std::error::Error for ServeError {}

impl From<SolveError> for ServeError {
    fn from(e: SolveError) -> ServeError {
        ServeError::Solver(e)
    }
}

pub(crate) type SolveReply = Sender<Result<Vec<f64>, ServeError>>;
pub(crate) type RotateReply = Sender<Result<(), ServeError>>;

/// One tenant solve request: a single RHS against a cached session at a
/// given λ (multi-RHS workloads submit several — the coalescer re-batches
/// them into one panel anyway).
pub(crate) struct SolveItem {
    pub sid: u64,
    pub lambda: f64,
    pub rhs: Vec<f64>,
    pub reply: SolveReply,
    /// When the tenant submitted (for [`ServeError::DeadlineExceeded`]
    /// partial-progress stats).
    pub enqueued: Instant,
    /// When the dispatcher must stop burning time on this request.
    pub deadline: Instant,
}

/// One tenant window rotation (the PR-5 streaming `update_rows`).
pub(crate) struct RotateItem {
    pub sid: u64,
    pub removed: Vec<usize>,
    pub added: Mat,
    pub reply: RotateReply,
    pub enqueued: Instant,
    pub deadline: Instant,
}

pub(crate) enum Pending {
    Solve(SolveItem),
    Rotate(RotateItem),
}

/// Solves bound for one `solve_many` panel: same session, same λ bits.
/// `rows[i]`'s answer goes to `replies[i]`.
pub(crate) struct SolveGroup {
    pub sid: u64,
    pub lambda: f64,
    pub rows: Vec<Vec<f64>>,
    pub replies: Vec<SolveReply>,
    /// Earliest submit time across the group's requests.
    pub enqueued: Instant,
    /// Tightest deadline across the group's requests: recovery work on
    /// a coalesced panel must respect its most impatient member.
    pub deadline: Instant,
}

/// Group drained solves into dispatch panels. With `coalesce` on,
/// requests sharing `(sid, λ)` merge into one group — keyed on λ's
/// **bits** so only exactly-equal damping coalesces; groups keep first-
/// arrival order and rows keep arrival order within a group (replies
/// line up with panel rows). With `coalesce` off every request is its
/// own group — the serial baseline the serving bench compares against.
pub(crate) fn coalesce_solves(items: Vec<SolveItem>, coalesce: bool) -> Vec<SolveGroup> {
    let mut groups: Vec<SolveGroup> = Vec::new();
    let mut index: HashMap<(u64, u64), usize> = HashMap::new();
    for it in items {
        if coalesce {
            let key = (it.sid, it.lambda.to_bits());
            if let Some(&g) = index.get(&key) {
                groups[g].rows.push(it.rhs);
                groups[g].replies.push(it.reply);
                groups[g].enqueued = groups[g].enqueued.min(it.enqueued);
                groups[g].deadline = groups[g].deadline.min(it.deadline);
                continue;
            }
            index.insert(key, groups.len());
        }
        groups.push(SolveGroup {
            sid: it.sid,
            lambda: it.lambda,
            rows: vec![it.rhs],
            replies: vec![it.reply],
            enqueued: it.enqueued,
            deadline: it.deadline,
        });
    }
    groups
}

struct QueueState {
    items: VecDeque<Pending>,
    stopped: bool,
}

/// Depth-bounded MPSC dispatch queue: producers (tenant threads) reject
/// at `depth` with a retry-after hint, the single consumer (dispatcher)
/// drains whole ticks at a time.
pub(crate) struct RequestQueue {
    inner: Mutex<QueueState>,
    cv: Condvar,
    depth: usize,
    retry_after_ms: u64,
}

impl RequestQueue {
    pub(crate) fn new(depth: usize, retry_after_ms: u64) -> RequestQueue {
        assert!(depth > 0);
        RequestQueue {
            inner: Mutex::new(QueueState { items: VecDeque::new(), stopped: false }),
            cv: Condvar::new(),
            depth,
            retry_after_ms: retry_after_ms.max(1),
        }
    }

    /// Admit or reject one request — never blocks, never grows past
    /// `depth`.
    pub(crate) fn try_push(&self, p: Pending) -> Result<(), ServeError> {
        let mut g = self.inner.lock().unwrap();
        if g.stopped {
            return Err(ServeError::ShuttingDown);
        }
        if g.items.len() >= self.depth {
            return Err(ServeError::Overloaded { retry_after_ms: self.retry_after_ms });
        }
        g.items.push_back(p);
        drop(g);
        self.cv.notify_one();
        Ok(())
    }

    /// Dispatcher side: block up to `timeout` for the queue to become
    /// non-empty (or the server to stop). Returns whether items are
    /// waiting.
    pub(crate) fn wait_nonempty(&self, timeout: Duration) -> bool {
        let g = self.inner.lock().unwrap();
        if !g.items.is_empty() {
            return true;
        }
        if g.stopped {
            return false;
        }
        let (g, _) = self.cv.wait_timeout(g, timeout).unwrap();
        !g.items.is_empty()
    }

    /// Dispatcher side: take everything queued so far.
    pub(crate) fn drain(&self) -> Vec<Pending> {
        let mut g = self.inner.lock().unwrap();
        g.items.drain(..).collect()
    }

    /// Reject all future pushes and wake the dispatcher.
    pub(crate) fn stop(&self) {
        self.inner.lock().unwrap().stopped = true;
        self.cv.notify_all();
    }

    pub(crate) fn is_stopped(&self) -> bool {
        self.inner.lock().unwrap().stopped
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    fn solve_item(sid: u64, lambda: f64, tag: f64) -> SolveItem {
        let (tx, _rx) = channel();
        let now = Instant::now();
        SolveItem {
            sid,
            lambda,
            rhs: vec![tag; 3],
            reply: tx,
            enqueued: now,
            deadline: now + Duration::from_secs(5),
        }
    }

    #[test]
    fn queue_rejects_at_depth_with_retry_hint() {
        let q = RequestQueue::new(2, 7);
        q.try_push(Pending::Solve(solve_item(1, 0.1, 0.0))).unwrap();
        q.try_push(Pending::Solve(solve_item(1, 0.1, 1.0))).unwrap();
        match q.try_push(Pending::Solve(solve_item(1, 0.1, 2.0))) {
            Err(ServeError::Overloaded { retry_after_ms }) => assert_eq!(retry_after_ms, 7),
            other => panic!("expected Overloaded, got {:?}", other.map(|_| ())),
        }
        assert!(ServeError::Overloaded { retry_after_ms: 7 }.is_retryable());
        // Draining frees capacity again.
        assert_eq!(q.drain().len(), 2);
        q.try_push(Pending::Solve(solve_item(1, 0.1, 3.0))).unwrap();
    }

    #[test]
    fn retry_after_hints_are_exposed_and_pinned() {
        // The satellite-3 contract: both admission rejections carry the
        // hint the Client sleep-and-retry loop consumes, verbatim.
        let over = ServeError::Overloaded { retry_after_ms: 7 };
        assert_eq!(over.retry_after_ms(), Some(7));
        let budget =
            ServeError::OverBudget { required_bytes: 100, budget_bytes: 64, retry_after_ms: 13 };
        assert_eq!(budget.retry_after_ms(), Some(13));
        assert!(budget.is_retryable());
        // Non-admission errors carry no hint.
        assert_eq!(ServeError::UnknownSession(4).retry_after_ms(), None);
        assert_eq!(ServeError::TenantLimit { tenants: 2 }.retry_after_ms(), None);
        assert_eq!(
            ServeError::DeadlineExceeded { elapsed_ms: 9, retries: 2 }.retry_after_ms(),
            None
        );
        // And the queue's own hint is the configured value, not a default.
        let q = RequestQueue::new(1, 23);
        q.try_push(Pending::Solve(solve_item(1, 0.1, 0.0))).unwrap();
        match q.try_push(Pending::Solve(solve_item(1, 0.1, 1.0))) {
            Err(e) => assert_eq!(e.retry_after_ms(), Some(23)),
            other => panic!("expected Overloaded, got {:?}", other.map(|_| ())),
        }
    }

    #[test]
    fn deadline_exceeded_is_terminal_and_reports_progress() {
        let e = ServeError::DeadlineExceeded { elapsed_ms: 120, retries: 3 };
        assert!(!e.is_retryable());
        let msg = e.to_string();
        assert!(msg.contains("120 ms") && msg.contains("3 retries"), "{msg}");
    }

    #[test]
    fn coalesced_group_takes_the_tightest_deadline() {
        let mut early = solve_item(1, 0.1, 0.0);
        let tight = early.enqueued + Duration::from_millis(10);
        early.deadline = tight + Duration::from_secs(60);
        let mut impatient = solve_item(1, 0.1, 1.0);
        impatient.deadline = tight;
        let groups = coalesce_solves(vec![early, impatient], true);
        assert_eq!(groups.len(), 1);
        assert_eq!(groups[0].deadline, tight);
    }

    #[test]
    fn stopped_queue_rejects_as_shutting_down() {
        let q = RequestQueue::new(4, 1);
        q.stop();
        assert!(q.is_stopped());
        match q.try_push(Pending::Solve(solve_item(1, 0.1, 0.0))) {
            Err(ServeError::ShuttingDown) => {}
            other => panic!("expected ShuttingDown, got {:?}", other.map(|_| ())),
        }
        assert!(!ServeError::ShuttingDown.is_retryable());
    }

    #[test]
    fn coalesce_groups_by_session_and_lambda_bits() {
        let items = vec![
            solve_item(1, 0.1, 0.0),
            solve_item(2, 0.1, 1.0),
            solve_item(1, 0.1, 2.0),
            solve_item(1, 0.2, 3.0),
            solve_item(1, 0.1, 4.0),
        ];
        let groups = coalesce_solves(items, true);
        assert_eq!(groups.len(), 3);
        // First-arrival group order…
        assert_eq!((groups[0].sid, groups[0].lambda), (1, 0.1));
        assert_eq!((groups[1].sid, groups[1].lambda), (2, 0.1));
        assert_eq!((groups[2].sid, groups[2].lambda), (1, 0.2));
        // …and arrival order within the coalesced group, so replies
        // line up with panel rows.
        let tags: Vec<f64> = groups[0].rows.iter().map(|r| r[0]).collect();
        assert_eq!(tags, vec![0.0, 2.0, 4.0]);
        assert_eq!(groups[0].replies.len(), 3);
    }

    #[test]
    fn coalesce_off_is_one_group_per_request() {
        let items = vec![
            solve_item(1, 0.1, 0.0),
            solve_item(1, 0.1, 1.0),
            solve_item(1, 0.1, 2.0),
        ];
        let groups = coalesce_solves(items, false);
        assert_eq!(groups.len(), 3);
        assert!(groups.iter().all(|g| g.rows.len() == 1));
    }

    #[test]
    fn wait_nonempty_wakes_on_push_and_stop() {
        use std::sync::Arc;
        let q = Arc::new(RequestQueue::new(4, 1));
        // Empty + timeout → false.
        assert!(!q.wait_nonempty(Duration::from_millis(5)));
        let q2 = q.clone();
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(10));
            q2.try_push(Pending::Solve(solve_item(1, 0.1, 0.0))).unwrap();
        });
        assert!(q.wait_nonempty(Duration::from_millis(500)));
        h.join().unwrap();
        q.drain();
        // Stop wakes a waiting dispatcher with "nothing to do".
        let q3 = q.clone();
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(10));
            q3.stop();
        });
        assert!(!q.wait_nonempty(Duration::from_millis(500)));
        h.join().unwrap();
    }
}
