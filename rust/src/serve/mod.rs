//! Multi-tenant damped-solve serving layer (PR 7).
//!
//! The paper's solve — `(SᵀS + λI)x = v` via the n×n Gram dual — is the
//! inner loop of every NGD/SR consumer, and the ROADMAP north-star
//! ("heavy traffic from millions of users") needs more than one trainer
//! driving one in-process pool. This module is that front-end:
//!
//! - [`server`] — the [`server::Server`]/[`server::Client`] pair: tenants
//!   open sessions (score matrix → cached λ-independent staging), stream
//!   single-RHS solves and window rotations, and a dispatcher thread
//!   coalesces compatible RHS across tenants into one `solve_many` panel
//!   per tick. Admission is reject-with-retry-after — never OOM, never
//!   unbounded queues.
//! - [`queue`] — the bounded request queue, the coalescing policy
//!   (group by `(session, λ-bits)`, preserve arrival order), and the
//!   typed [`queue::ServeError`] with its retryable/fatal split.
//! - [`transport`] — the [`transport::ShardTransport`] trait that lets
//!   `coordinator/sharded.rs` shard workers live in-process (bounded
//!   channels) or out-of-process (length-prefixed Unix-domain-socket
//!   frames), bit-identically.
//!
//! Fault tolerance (PR 8) layers on top without touching the compute
//! path:
//!
//! - [`supervisor`] — worker health probes + respawn ([`supervisor::Supervisor`]),
//!   the durable [`supervisor::SessionRecord`] (window snapshot + rotation
//!   log, replayed through `update_rows` so a recovered factor matches an
//!   unfailed run), and the deterministic-jitter [`supervisor::RetryPolicy`].
//! - [`chaos`] — scripted fault schedules (kill-during-factor,
//!   stall-during-panel, corrupt-frame, respawn storms) asserting every
//!   schedule ends with correct answers and zero leaked sessions; the CLI
//!   front door is `dngd chaos`.
//!
//! The CLI front door is `dngd serve` (self-test + demo traffic); the
//! sustained-traffic benchmark is `benches/serving.rs` →
//! `BENCH_PR7.json`, and the recovery-latency benchmark writes
//! `BENCH_PR8.json`.

pub mod chaos;
pub mod queue;
pub mod server;
pub mod supervisor;
pub mod transport;

pub use chaos::{ChaosOptions, ChaosReport, FaultSchedule};
pub use queue::ServeError;
pub use server::{Client, ServeOptions, ServeStats, Server, SolveTicket};
pub use supervisor::{HealReport, RetryPolicy, RotationEntry, SessionRecord, Supervisor};
pub use transport::{ChannelTransport, ShardTransport, TransportError, TransportKind};
#[cfg(unix)]
pub use transport::SocketTransport;
