//! PJRT runtime — loads and executes the AOT artifacts produced by
//! `python/compile/aot.py`, keeping Python strictly off the request path.
//!
//! Interchange format is **HLO text** (not serialized `HloModuleProto`):
//! jax ≥ 0.5 emits protos with 64-bit instruction ids that the crate's
//! xla_extension 0.5.1 rejects; the text parser reassigns ids and
//! round-trips cleanly (see `/opt/xla-example/README.md` and
//! `python/compile/aot.py`).
//!
//! * [`ArtifactRegistry`] — scans `artifacts/` for shape-keyed HLO
//!   modules (`solve_n{n}_m{m}.hlo.txt`, …) at startup;
//! * [`PjrtSolver`] — a [`crate::solver::DampedSolver`] whose hot path is
//!   a compiled XLA executable (the L2 JAX solve, which itself inlines
//!   the L1 Pallas kernels);
//! * [`Backend`] — dispatch between the PJRT path and the native Rust
//!   path, by shape availability.

pub mod artifacts;
pub mod pjrt;

pub use artifacts::{ArtifactKind, ArtifactRegistry};
pub use pjrt::PjrtSolver;

use crate::linalg::Mat;
use crate::solver::{CholSolver, DampedSolver, SolveError};

/// Execution backend for the damped solve.
pub enum Backend {
    /// Compiled XLA executable (fixed shape).
    Pjrt(PjrtSolver),
    /// Native Rust implementation (any shape).
    Native(CholSolver),
}

impl Backend {
    /// Pick PJRT when an artifact for (n, m) exists, else native.
    /// `threads` configures the native SYRK parallelism.
    pub fn select(registry: &ArtifactRegistry, n: usize, m: usize, threads: usize) -> Backend {
        match registry.find(ArtifactKind::Solve, n, m) {
            Some(path) => match PjrtSolver::load(&path, n, m) {
                Ok(s) => Backend::Pjrt(s),
                Err(e) => {
                    eprintln!(
                        "[runtime] artifact {} failed to load ({e}); falling back to native",
                        path.display()
                    );
                    Backend::Native(CholSolver::with_threads(threads))
                }
            },
            None => Backend::Native(CholSolver::with_threads(threads)),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Backend::Pjrt(_) => "pjrt",
            Backend::Native(_) => "native",
        }
    }

    pub fn solve(&self, s: &Mat, v: &[f64], lambda: f64) -> Result<Vec<f64>, SolveError> {
        match self {
            Backend::Pjrt(p) => p.solve(s, v, lambda),
            Backend::Native(c) => c.solve(s, v, lambda),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_falls_back_to_native_without_artifacts() {
        let reg = ArtifactRegistry::scan(std::path::Path::new("/nonexistent-dir"));
        let b = Backend::select(&reg, 8, 32, 1);
        assert_eq!(b.name(), "native");
        // And it solves.
        let mut rng = crate::data::rng::Rng::seed_from(1);
        let s = Mat::randn(8, 32, &mut rng);
        let v = vec![1.0; 32];
        let x = b.solve(&s, &v, 0.1).unwrap();
        assert!(crate::solver::residual_norm(&s, &x, &v, 0.1) < 1e-8);
    }
}
