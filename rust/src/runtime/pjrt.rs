//! PJRT-backed damped solver: compile once, execute per request.
//!
//! The artifact is the L2 JAX function
//! `solve(S, v, λ) = (v − Sᵀ·chol_solve(SSᵀ+λĨ, Sv))/λ` lowered at a
//! fixed (n, m) with f32 dtypes (JAX default; the AOT pipeline and this
//! loader agree on that contract). Conversions f64 ⇄ f32 happen at the
//! boundary only.

use crate::linalg::Mat;
use crate::solver::{DampedSolver, SolveError};
use std::path::Path;
use std::sync::Mutex;

/// A compiled fixed-shape solve executable on the PJRT CPU client.
pub struct PjrtSolver {
    n: usize,
    m: usize,
    // PJRT structures are not Sync; the executable is guarded so the
    // solver can be shared across coordinator threads.
    exe: Mutex<xla::PjRtLoadedExecutable>,
}

impl PjrtSolver {
    /// Load HLO text, compile on the CPU client.
    pub fn load(path: &Path, n: usize, m: usize) -> Result<PjrtSolver, SolveError> {
        let client = xla::PjRtClient::cpu().map_err(xla_err)?;
        let proto = xla::HloModuleProto::from_text_file(path).map_err(xla_err)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp).map_err(xla_err)?;
        Ok(PjrtSolver { n, m, exe: Mutex::new(exe) })
    }

    pub fn shape(&self) -> (usize, usize) {
        (self.n, self.m)
    }
}

fn xla_err(e: xla::Error) -> SolveError {
    SolveError::BadInput(format!("pjrt: {e}"))
}

impl DampedSolver for PjrtSolver {
    fn name(&self) -> &'static str {
        "pjrt-chol"
    }

    fn solve(&self, s: &Mat, v: &[f64], lambda: f64) -> Result<Vec<f64>, SolveError> {
        if s.shape() != (self.n, self.m) || v.len() != self.m {
            return Err(SolveError::BadInput(format!(
                "artifact compiled for shape ({}, {}), got S {:?} / v {}",
                self.n,
                self.m,
                s.shape(),
                v.len()
            )));
        }
        if lambda <= 0.0 {
            return Err(SolveError::BadInput(format!("damping λ must be > 0, got {lambda}")));
        }
        // f64 → f32 at the boundary (artifact dtype contract).
        let s32: Vec<f32> = s.as_slice().iter().map(|&x| x as f32).collect();
        let v32: Vec<f32> = v.iter().map(|&x| x as f32).collect();
        let s_lit = xla::Literal::vec1(&s32)
            .reshape(&[self.n as i64, self.m as i64])
            .map_err(xla_err)?;
        let v_lit = xla::Literal::vec1(&v32);
        let l_lit = xla::Literal::scalar(lambda as f32);

        let exe = self.exe.lock().unwrap();
        let result = exe.execute::<xla::Literal>(&[s_lit, v_lit, l_lit]).map_err(xla_err)?;
        let lit = result[0][0].to_literal_sync().map_err(xla_err)?;
        // aot.py lowers with return_tuple=True → 1-tuple.
        let out = lit.to_tuple1().map_err(xla_err)?;
        let x32 = out.to_vec::<f32>().map_err(xla_err)?;
        if x32.len() != self.m {
            return Err(SolveError::BadInput(format!(
                "artifact returned {} values, expected {}",
                x32.len(),
                self.m
            )));
        }
        Ok(x32.into_iter().map(f64::from).collect())
    }
}

// Tests that require real artifacts live in `rust/tests/runtime_artifacts.rs`
// (they skip gracefully when `make artifacts` has not run).
