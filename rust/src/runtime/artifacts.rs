//! Shape-keyed artifact registry.
//!
//! `make artifacts` produces files named
//!
//! ```text
//! artifacts/solve_n{n}_m{m}.hlo.txt       — the damped-solve graph
//! artifacts/gram_n{n}_m{m}.hlo.txt        — SYRK-only graph (ablation)
//! artifacts/lm_step_*.hlo.txt             — model fwd+scores graph
//! ```
//!
//! The registry scans once at startup and resolves (kind, n, m) → path.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Which computation an artifact holds.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum ArtifactKind {
    Solve,
    Gram,
}

impl ArtifactKind {
    fn prefix(self) -> &'static str {
        match self {
            ArtifactKind::Solve => "solve",
            ArtifactKind::Gram => "gram",
        }
    }
}

/// Registry of discovered artifacts.
#[derive(Debug, Default)]
pub struct ArtifactRegistry {
    entries: BTreeMap<(ArtifactKind, usize, usize), PathBuf>,
}

impl ArtifactRegistry {
    /// Scan a directory (missing dir = empty registry; callers fall back
    /// to the native path, so a fresh checkout works without `make
    /// artifacts`).
    pub fn scan(dir: &Path) -> ArtifactRegistry {
        let mut entries = BTreeMap::new();
        let Ok(rd) = std::fs::read_dir(dir) else {
            return ArtifactRegistry { entries };
        };
        for entry in rd.flatten() {
            let path = entry.path();
            let Some(name) = path.file_name().and_then(|n| n.to_str()) else { continue };
            let Some(stem) = name.strip_suffix(".hlo.txt") else { continue };
            for kind in [ArtifactKind::Solve, ArtifactKind::Gram] {
                if let Some(rest) = stem.strip_prefix(&format!("{}_", kind.prefix())) {
                    if let Some((n, m)) = parse_shape(rest) {
                        entries.insert((kind, n, m), path.clone());
                    }
                }
            }
        }
        ArtifactRegistry { entries }
    }

    /// Look up an artifact for an exact shape.
    pub fn find(&self, kind: ArtifactKind, n: usize, m: usize) -> Option<PathBuf> {
        self.entries.get(&(kind, n, m)).cloned()
    }

    /// All known (kind, n, m) triples.
    pub fn list(&self) -> Vec<(ArtifactKind, usize, usize)> {
        self.entries.keys().cloned().collect()
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

/// Parse `"n{n}_m{m}"`.
fn parse_shape(s: &str) -> Option<(usize, usize)> {
    let rest = s.strip_prefix('n')?;
    let (n_str, m_part) = rest.split_once("_m")?;
    let n = n_str.parse().ok()?;
    let m = m_part.parse().ok()?;
    Some((n, m))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_shape_names() {
        assert_eq!(parse_shape("n256_m100000"), Some((256, 100000)));
        assert_eq!(parse_shape("n8_m32"), Some((8, 32)));
        assert_eq!(parse_shape("256_m100"), None);
        assert_eq!(parse_shape("n256m100"), None);
        assert_eq!(parse_shape("nX_m100"), None);
    }

    #[test]
    fn scans_directory() {
        let dir = std::env::temp_dir().join("dngd_test_artifacts");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("solve_n16_m64.hlo.txt"), "dummy").unwrap();
        std::fs::write(dir.join("gram_n16_m64.hlo.txt"), "dummy").unwrap();
        std::fs::write(dir.join("unrelated.txt"), "dummy").unwrap();
        std::fs::write(dir.join("solve_garbage.hlo.txt"), "dummy").unwrap();
        let reg = ArtifactRegistry::scan(&dir);
        assert_eq!(reg.len(), 2);
        assert!(reg.find(ArtifactKind::Solve, 16, 64).is_some());
        assert!(reg.find(ArtifactKind::Gram, 16, 64).is_some());
        assert!(reg.find(ArtifactKind::Solve, 16, 65).is_none());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_dir_is_empty() {
        let reg = ArtifactRegistry::scan(Path::new("/definitely/not/here"));
        assert!(reg.is_empty());
    }
}
