//! Softmax-classifier MLP with per-sample score rows.
//!
//! Architecture: `d → h₁ → … → h_k → K` with tanh hidden activations and
//! a softmax output; loss is mean NLL. The manual backward pass runs once
//! per sample, writing `∂log p(y_i|x_i)/∂θ` into row i of the score
//! matrix (scaled 1/√n). Validated against central finite differences.

use super::BatchEval;
use crate::data::rng::Rng;
use crate::linalg::Mat;

/// Multi-layer perceptron classifier.
#[derive(Clone, Debug)]
pub struct Mlp {
    /// Layer widths, e.g. `[d, 32, 32, K]`.
    pub sizes: Vec<usize>,
}

impl Mlp {
    pub fn new(sizes: Vec<usize>) -> Self {
        assert!(sizes.len() >= 2, "need at least input and output layers");
        Mlp { sizes }
    }

    /// Total parameter count (weights + biases per layer).
    pub fn num_params(&self) -> usize {
        self.sizes
            .windows(2)
            .map(|w| w[0] * w[1] + w[1])
            .sum()
    }

    /// Xavier-style init.
    pub fn init_params(&self, rng: &mut Rng) -> Vec<f64> {
        let mut p = Vec::with_capacity(self.num_params());
        for w in self.sizes.windows(2) {
            let (fan_in, fan_out) = (w[0], w[1]);
            let scale = (2.0 / (fan_in + fan_out) as f64).sqrt();
            for _ in 0..fan_in * fan_out {
                p.push(scale * rng.normal());
            }
            for _ in 0..fan_out {
                p.push(0.0);
            }
        }
        p
    }

    /// Forward pass returning per-layer activations (post-tanh, plus the
    /// input as layer 0) and the final logits.
    fn forward(&self, params: &[f64], x: &[f64]) -> (Vec<Vec<f64>>, Vec<f64>) {
        let mut acts = vec![x.to_vec()];
        let mut offset = 0;
        let last = self.sizes.len() - 2;
        let mut cur = x.to_vec();
        for (li, w) in self.sizes.windows(2).enumerate() {
            let (fi, fo) = (w[0], w[1]);
            let wmat = &params[offset..offset + fi * fo];
            let bias = &params[offset + fi * fo..offset + fi * fo + fo];
            offset += fi * fo + fo;
            let mut next = vec![0.0; fo];
            for o in 0..fo {
                let mut s = bias[o];
                let row = &wmat[o * fi..(o + 1) * fi];
                for i in 0..fi {
                    s += row[i] * cur[i];
                }
                next[o] = if li == last { s } else { s.tanh() };
            }
            acts.push(next.clone());
            cur = next;
        }
        let logits = acts.pop().unwrap();
        (acts, logits)
    }

    /// Per-sample backward: given d(logits), write ∂/∂θ into `out`
    /// (accumulating with weight `scale`).
    fn backward(
        &self,
        params: &[f64],
        acts: &[Vec<f64>],
        mut dlogits: Vec<f64>,
        scale: f64,
        out: &mut [f64],
    ) {
        // Walk layers in reverse. acts[li] is the input to layer li.
        let mut offsets = Vec::with_capacity(self.sizes.len() - 1);
        let mut off = 0;
        for w in self.sizes.windows(2) {
            offsets.push(off);
            off += w[0] * w[1] + w[1];
        }
        let lcount = self.sizes.len() - 1;
        let mut dcur = std::mem::take(&mut dlogits);
        for li in (0..lcount).rev() {
            let (fi, fo) = (self.sizes[li], self.sizes[li + 1]);
            let base = offsets[li];
            let wmat = &params[base..base + fi * fo];
            let input = &acts[li];
            // Weight/bias grads.
            for o in 0..fo {
                let d = dcur[o] * scale;
                if d != 0.0 {
                    let wrow = base + o * fi;
                    for i in 0..fi {
                        out[wrow + i] += d * input[i];
                    }
                    out[base + fi * fo + o] += d;
                }
            }
            if li > 0 {
                // d(input) then through the tanh of the previous layer.
                let mut dprev = vec![0.0; fi];
                for o in 0..fo {
                    let d = dcur[o];
                    if d != 0.0 {
                        let row = &wmat[o * fi..(o + 1) * fi];
                        for i in 0..fi {
                            dprev[i] += d * row[i];
                        }
                    }
                }
                // acts[li] holds tanh outputs of layer li−1.
                for i in 0..fi {
                    let t = input[i];
                    dprev[i] *= 1.0 - t * t;
                }
                dcur = dprev;
            }
        }
    }

    /// Evaluate a batch: inputs `x` (n×d), integer class targets `y`.
    /// Returns loss, gradient and the 1/√n-scaled score matrix.
    pub fn batch_eval(&self, params: &[f64], x: &Mat, y: &[usize]) -> BatchEval {
        let n = x.rows();
        assert_eq!(y.len(), n);
        assert_eq!(x.cols(), self.sizes[0]);
        let m = self.num_params();
        let k = *self.sizes.last().unwrap();
        let inv_sqrt_n = 1.0 / (n as f64).sqrt();

        let mut scores = Mat::zeros(n, m);
        let mut loss = 0.0;
        for i in 0..n {
            let (acts, logits) = self.forward(params, x.row(i));
            // log-softmax.
            let maxl = logits.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            let zsum: f64 = logits.iter().map(|l| (l - maxl).exp()).sum();
            let logz = maxl + zsum.ln();
            loss -= logits[y[i]] - logz;
            // d(log p_y)/d logits = e_y − softmax.
            let mut d: Vec<f64> = logits.iter().map(|l| -((l - maxl).exp() / zsum)).collect();
            d[y[i]] += 1.0;
            debug_assert_eq!(d.len(), k);
            self.backward(params, &acts, d, inv_sqrt_n, scores.row_mut(i));
        }
        loss /= n as f64;
        let grad = super::grad_from_scores(&scores);
        BatchEval { loss, grad, scores }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::tasks::classification_task;

    fn fd_grad(mlp: &Mlp, params: &[f64], x: &Mat, y: &[usize], eps: f64) -> Vec<f64> {
        let mut g = vec![0.0; params.len()];
        let mut p = params.to_vec();
        for j in 0..params.len() {
            p[j] = params[j] + eps;
            let lp = mlp.batch_eval(&p, x, y).loss;
            p[j] = params[j] - eps;
            let lm = mlp.batch_eval(&p, x, y).loss;
            p[j] = params[j];
            g[j] = (lp - lm) / (2.0 * eps);
        }
        g
    }

    #[test]
    fn gradient_matches_finite_differences() {
        let mut rng = Rng::seed_from(220);
        let mlp = Mlp::new(vec![3, 5, 4]);
        let params = mlp.init_params(&mut rng);
        let (x, yf) = classification_task(6, 3, 1.0, &mut rng);
        let y: Vec<usize> = yf.iter().map(|&v| usize::from(v > 0.0)).collect();
        let eval = mlp.batch_eval(&params, &x, &y);
        let fd = fd_grad(&mlp, &params, &x, &y, 1e-5);
        for (a, b) in eval.grad.iter().zip(&fd) {
            assert!((a - b).abs() < 1e-6, "analytic {a} vs fd {b}");
        }
    }

    #[test]
    fn per_sample_scores_sum_to_grad() {
        let mut rng = Rng::seed_from(221);
        let mlp = Mlp::new(vec![4, 6, 3]);
        let params = mlp.init_params(&mut rng);
        let (x, yf) = classification_task(10, 4, 1.0, &mut rng);
        let y: Vec<usize> = yf.iter().map(|&v| usize::from(v > 0.0)).collect();
        let eval = mlp.batch_eval(&params, &x, &y);
        let derived = super::super::grad_from_scores(&eval.scores);
        for (a, b) in eval.grad.iter().zip(&derived) {
            assert!((a - b).abs() < 1e-12);
        }
    }

    #[test]
    fn per_sample_row_is_single_sample_gradient() {
        // Row i of √n·S must equal the gradient of log p for sample i alone.
        let mut rng = Rng::seed_from(222);
        let mlp = Mlp::new(vec![3, 4, 2]);
        let params = mlp.init_params(&mut rng);
        let (x, yf) = classification_task(5, 3, 1.0, &mut rng);
        let y: Vec<usize> = yf.iter().map(|&v| usize::from(v > 0.0)).collect();
        let eval = mlp.batch_eval(&params, &x, &y);
        let i = 2;
        let xi = x.slice_rows(i, i + 1);
        let single = mlp.batch_eval(&params, &xi, &y[i..i + 1]);
        // For n=1: grad = −S_row·√1 ⇒ score row = −grad.
        let sqrt_n = (5f64).sqrt();
        for j in 0..params.len() {
            let from_batch = eval.scores[(i, j)] * sqrt_n;
            let from_single = -single.grad[j];
            assert!((from_batch - from_single).abs() < 1e-10);
        }
    }

    #[test]
    fn training_reduces_loss() {
        let mut rng = Rng::seed_from(223);
        let mlp = Mlp::new(vec![4, 8, 2]);
        let mut params = mlp.init_params(&mut rng);
        let (x, yf) = classification_task(60, 4, 2.0, &mut rng);
        let y: Vec<usize> = yf.iter().map(|&v| usize::from(v > 0.0)).collect();
        let l0 = mlp.batch_eval(&params, &x, &y).loss;
        let mut opt = crate::ngd::NaturalGradient::new(
            Box::new(crate::solver::CholSolver::default()),
            crate::ngd::DampingSchedule::Constant { lambda: 1e-3 },
            0.5,
        );
        for _ in 0..15 {
            let e = mlp.batch_eval(&params, &x, &y);
            opt.step(&mut params, &e.scores, &e.grad, e.loss).unwrap();
        }
        let l1 = mlp.batch_eval(&params, &x, &y).loss;
        assert!(l1 < 0.3 * l0, "loss {l0} → {l1}");
    }

    #[test]
    fn num_params_counts_weights_and_biases() {
        let mlp = Mlp::new(vec![3, 5, 2]);
        assert_eq!(mlp.num_params(), 3 * 5 + 5 + 5 * 2 + 2);
        assert_eq!(mlp.init_params(&mut Rng::seed_from(0)).len(), mlp.num_params());
    }
}
