//! Native model substrate.
//!
//! NGD needs per-sample score rows `S_ij = (1/√n)·∂log P_θ(x_i)/∂θ_j`
//! (paper §2), i.e. *per-sample* gradients, not just the batch gradient.
//! These models implement manual reverse-mode differentiation that emits
//! one score row per sample:
//!
//! * [`Mlp`] — softmax-classifier MLP (tanh hidden layers);
//! * [`Transformer`] — a small GPT-style decoder (causal multi-head
//!   attention, GELU MLP, pre-LayerNorm) for the char-LM end-to-end run.
//!
//! Both are validated against central finite differences in their tests,
//! and against the JAX L2 model through the AOT artifact integration test
//! (`rust/tests/runtime_artifacts.rs`).
//!
//! For log-likelihood losses the batch gradient is a linear image of the
//! score matrix, `v = −(1/√n)·colsum(S)`; [`BatchEval`] carries both so
//! callers can exploit or ignore that structure (the RVB method requires
//! it, Algorithm 1 does not — see §3).

pub mod mlp;
pub mod transformer;

pub use mlp::Mlp;
pub use transformer::{Transformer, TransformerConfig};

use crate::linalg::Mat;

/// One batch evaluation: loss, gradient, and the score matrix.
pub struct BatchEval {
    /// Mean negative log-likelihood over the batch.
    pub loss: f64,
    /// Gradient of the mean loss w.r.t. all parameters (length m).
    pub grad: Vec<f64>,
    /// Score matrix S (n×m), rows scaled by 1/√n per the paper.
    pub scores: Mat,
}

/// Derive the batch loss gradient from score rows for NLL losses:
/// `v = −(1/√n)·Σ_i S_i`.
pub fn grad_from_scores(scores: &Mat) -> Vec<f64> {
    let (n, m) = scores.shape();
    let mut v = vec![0.0; m];
    for i in 0..n {
        let row = scores.row(i);
        for j in 0..m {
            v[j] += row[j];
        }
    }
    let scale = -1.0 / (n as f64).sqrt();
    for x in &mut v {
        *x *= scale;
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grad_from_scores_matches_definition() {
        let s = Mat::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let v = grad_from_scores(&s);
        let scale = -1.0 / 2f64.sqrt();
        assert!((v[0] - scale * 5.0).abs() < 1e-15);
        assert!((v[2] - scale * 9.0).abs() < 1e-15);
    }
}
