//! GPT-style decoder-only transformer with per-sample score rows.
//!
//! Pre-LayerNorm blocks: `x += Wo·MHA(LN1 x)`; `x += W2·gelu(W1·LN2 x)`,
//! final LayerNorm + linear head, next-token NLL at the last position.
//! The manual reverse pass produces one score row
//! `∂log p(y|context)/∂θ / √n` per sample — the S the NGD trainer feeds
//! to Algorithm 1. Validated against central finite differences (which is
//! why the implementation is kept scrupulously branch-free in the math).

use super::BatchEval;
use crate::data::rng::Rng;
use crate::linalg::Mat;

/// Transformer hyperparameters.
#[derive(Clone, Debug, PartialEq)]
pub struct TransformerConfig {
    pub vocab: usize,
    /// Embedding / residual width D.
    pub dim: usize,
    /// Attention heads (must divide `dim`).
    pub heads: usize,
    /// Decoder blocks.
    pub layers: usize,
    /// Context length C.
    pub context: usize,
    /// MLP hidden width (conventionally 4·dim).
    pub mlp_hidden: usize,
}

impl TransformerConfig {
    /// A small config suitable for CPU end-to-end runs.
    pub fn small(vocab: usize, context: usize) -> Self {
        TransformerConfig { vocab, dim: 16, heads: 2, layers: 2, context, mlp_hidden: 64 }
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.dim % self.heads != 0 {
            return Err(format!("heads {} must divide dim {}", self.heads, self.dim));
        }
        if self.vocab == 0 || self.context == 0 || self.layers == 0 {
            return Err("vocab, context and layers must be positive".into());
        }
        Ok(())
    }
}

/// Offsets of each parameter tensor in the flat parameter vector.
#[derive(Clone, Debug)]
struct Layout {
    wte: usize,
    wpe: usize,
    layers: Vec<LayerLayout>,
    lnf_g: usize,
    lnf_b: usize,
    head: usize,
    total: usize,
}

#[derive(Clone, Debug)]
struct LayerLayout {
    ln1_g: usize,
    ln1_b: usize,
    wq: usize,
    wk: usize,
    wv: usize,
    wo: usize,
    ln2_g: usize,
    ln2_b: usize,
    w1: usize,
    b1: usize,
    w2: usize,
    b2: usize,
}

impl Layout {
    fn new(c: &TransformerConfig) -> Layout {
        let (v, d, f, ctx) = (c.vocab, c.dim, c.mlp_hidden, c.context);
        let mut off = 0;
        let mut take = |len: usize| {
            let o = off;
            off += len;
            o
        };
        let wte = take(v * d);
        let wpe = take(ctx * d);
        let mut layers = Vec::with_capacity(c.layers);
        for _ in 0..c.layers {
            layers.push(LayerLayout {
                ln1_g: take(d),
                ln1_b: take(d),
                wq: take(d * d),
                wk: take(d * d),
                wv: take(d * d),
                wo: take(d * d),
                ln2_g: take(d),
                ln2_b: take(d),
                w1: take(f * d),
                b1: take(f),
                w2: take(d * f),
                b2: take(d),
            });
        }
        let lnf_g = take(d);
        let lnf_b = take(d);
        let head = take(v * d);
        Layout { wte, wpe, layers, lnf_g, lnf_b, head, total: off }
    }
}

/// Per-layer forward cache for one sample.
struct LayerCache {
    x_in: Vec<f64>,  // C×D residual entering the block
    ln1_mu: Vec<f64>,
    ln1_rstd: Vec<f64>,
    a: Vec<f64>,     // C×D LN1 output
    q: Vec<f64>,     // C×D
    k: Vec<f64>,
    v: Vec<f64>,
    att: Vec<f64>,   // H×C×C softmax weights (causal rows)
    o: Vec<f64>,     // C×D pre-Wo mix
    x_mid: Vec<f64>, // C×D residual after attention
    ln2_mu: Vec<f64>,
    ln2_rstd: Vec<f64>,
    bmat: Vec<f64>,  // C×D LN2 output
    u: Vec<f64>,     // C×F pre-GELU
    g: Vec<f64>,     // C×F post-GELU
}

struct ForwardCache {
    layers: Vec<LayerCache>,
    x_final: Vec<f64>, // C×D residual leaving the last block
    lnf_mu: f64,
    lnf_rstd: f64,
    f_last: Vec<f64>, // D, LN_f(x_final[last])
    logits: Vec<f64>, // V
}

const GELU_C: f64 = 0.7978845608028654; // √(2/π)
const GELU_A: f64 = 0.044715;

#[inline]
fn gelu(u: f64) -> f64 {
    0.5 * u * (1.0 + (GELU_C * (u + GELU_A * u * u * u)).tanh())
}

#[inline]
fn gelu_prime(u: f64) -> f64 {
    let inner = GELU_C * (u + GELU_A * u * u * u);
    let t = inner.tanh();
    0.5 * (1.0 + t) + 0.5 * u * (1.0 - t * t) * GELU_C * (1.0 + 3.0 * GELU_A * u * u)
}

/// `y = W·x` for row-major W (out×in).
fn matvec_into(w: &[f64], x: &[f64], out: &mut [f64]) {
    let fi = x.len();
    for (o, yo) in out.iter_mut().enumerate() {
        let row = &w[o * fi..(o + 1) * fi];
        let mut s = 0.0;
        for i in 0..fi {
            s += row[i] * x[i];
        }
        *yo = s;
    }
}

/// `dX += Wᵀ·dy`, `dW += dy ⊗ x` (the standard dense backward pair).
fn matvec_backward(w: &[f64], x: &[f64], dy: &[f64], dx: &mut [f64], dw: &mut [f64]) {
    let fi = x.len();
    for (o, &d) in dy.iter().enumerate() {
        if d == 0.0 {
            continue;
        }
        let row = &w[o * fi..(o + 1) * fi];
        let drow = &mut dw[o * fi..(o + 1) * fi];
        for i in 0..fi {
            dx[i] += d * row[i];
            drow[i] += d * x[i];
        }
    }
}

/// LayerNorm forward over a D-slice: returns (mu, rstd) and writes
/// `g·x̂+b` into `out`.
fn ln_forward(x: &[f64], g: &[f64], b: &[f64], out: &mut [f64]) -> (f64, f64) {
    let d = x.len();
    let mu = x.iter().sum::<f64>() / d as f64;
    let var = x.iter().map(|v| (v - mu) * (v - mu)).sum::<f64>() / d as f64;
    let rstd = 1.0 / (var + 1e-5).sqrt();
    for i in 0..d {
        out[i] = g[i] * (x[i] - mu) * rstd + b[i];
    }
    (mu, rstd)
}

/// LayerNorm backward: given dy, accumulates dg, db, and returns dx.
#[allow(clippy::too_many_arguments)]
fn ln_backward(
    x: &[f64],
    g: &[f64],
    mu: f64,
    rstd: f64,
    dy: &[f64],
    dg: &mut [f64],
    db: &mut [f64],
    dx: &mut [f64],
) {
    let d = x.len();
    let inv_d = 1.0 / d as f64;
    let mut mean_dxhat = 0.0;
    let mut mean_dxhat_xhat = 0.0;
    // First pass: accumulate means of dx̂ and dx̂·x̂.
    for i in 0..d {
        let xhat = (x[i] - mu) * rstd;
        let dxhat = dy[i] * g[i];
        mean_dxhat += dxhat;
        mean_dxhat_xhat += dxhat * xhat;
        dg[i] += dy[i] * xhat;
        db[i] += dy[i];
    }
    mean_dxhat *= inv_d;
    mean_dxhat_xhat *= inv_d;
    for i in 0..d {
        let xhat = (x[i] - mu) * rstd;
        let dxhat = dy[i] * g[i];
        dx[i] += rstd * (dxhat - mean_dxhat - xhat * mean_dxhat_xhat);
    }
}

/// Decoder-only transformer LM.
#[derive(Clone, Debug)]
pub struct Transformer {
    pub config: TransformerConfig,
    layout: Layout,
}

impl Transformer {
    pub fn new(config: TransformerConfig) -> Self {
        config.validate().expect("invalid transformer config");
        let layout = Layout::new(&config);
        Transformer { config, layout }
    }

    pub fn num_params(&self) -> usize {
        self.layout.total
    }

    /// GPT-2-style init: N(0, 0.02) weights, zero biases/LN-b, unit LN-g.
    pub fn init_params(&self, rng: &mut Rng) -> Vec<f64> {
        let mut p = vec![0.0; self.layout.total];
        let mut fill = |range: std::ops::Range<usize>, std: f64, p: &mut Vec<f64>| {
            for i in range {
                p[i] = std * rng.normal();
            }
        };
        let d = self.config.dim;
        let f = self.config.mlp_hidden;
        let v = self.config.vocab;
        fill(self.layout.wte..self.layout.wte + v * d, 0.02, &mut p);
        fill(self.layout.wpe..self.layout.wpe + self.config.context * d, 0.01, &mut p);
        for ll in &self.layout.layers {
            for i in ll.ln1_g..ll.ln1_g + d {
                p[i] = 1.0;
            }
            for i in ll.ln2_g..ll.ln2_g + d {
                p[i] = 1.0;
            }
            fill(ll.wq..ll.wq + d * d, 0.02, &mut p);
            fill(ll.wk..ll.wk + d * d, 0.02, &mut p);
            fill(ll.wv..ll.wv + d * d, 0.02, &mut p);
            // Residual-path projections scaled down by depth (GPT-2 trick).
            let res_std = 0.02 / (2.0 * self.config.layers as f64).sqrt();
            fill(ll.wo..ll.wo + d * d, res_std, &mut p);
            fill(ll.w1..ll.w1 + f * d, 0.02, &mut p);
            fill(ll.w2..ll.w2 + d * f, res_std, &mut p);
        }
        for i in self.layout.lnf_g..self.layout.lnf_g + d {
            p[i] = 1.0;
        }
        fill(self.layout.head..self.layout.head + v * d, 0.02, &mut p);
        p
    }

    /// Forward pass for one sample, caching everything backward needs.
    fn forward(&self, params: &[f64], tokens: &[u32]) -> ForwardCache {
        let c = &self.config;
        let (d, h, f, ctx) = (c.dim, c.heads, c.mlp_hidden, c.context);
        assert_eq!(tokens.len(), ctx, "expected a full context window");
        let dh = d / h;
        let inv_sqrt_dh = 1.0 / (dh as f64).sqrt();

        // Embedding.
        let mut x = vec![0.0; ctx * d];
        for p in 0..ctx {
            let t = tokens[p] as usize;
            assert!(t < c.vocab, "token id {t} out of vocab {}", c.vocab);
            let te = &params[self.layout.wte + t * d..self.layout.wte + (t + 1) * d];
            let pe = &params[self.layout.wpe + p * d..self.layout.wpe + (p + 1) * d];
            for i in 0..d {
                x[p * d + i] = te[i] + pe[i];
            }
        }

        let mut layers = Vec::with_capacity(c.layers);
        for ll in &self.layout.layers {
            let x_in = x.clone();
            // LN1 + QKV.
            let mut a = vec![0.0; ctx * d];
            let mut ln1_mu = vec![0.0; ctx];
            let mut ln1_rstd = vec![0.0; ctx];
            let g1 = &params[ll.ln1_g..ll.ln1_g + d];
            let b1v = &params[ll.ln1_b..ll.ln1_b + d];
            for p in 0..ctx {
                let (mu, rstd) =
                    ln_forward(&x_in[p * d..(p + 1) * d], g1, b1v, &mut a[p * d..(p + 1) * d]);
                ln1_mu[p] = mu;
                ln1_rstd[p] = rstd;
            }
            let mut q = vec![0.0; ctx * d];
            let mut k = vec![0.0; ctx * d];
            let mut v = vec![0.0; ctx * d];
            for p in 0..ctx {
                matvec_into(&params[ll.wq..ll.wq + d * d], &a[p * d..(p + 1) * d], &mut q[p * d..(p + 1) * d]);
                matvec_into(&params[ll.wk..ll.wk + d * d], &a[p * d..(p + 1) * d], &mut k[p * d..(p + 1) * d]);
                matvec_into(&params[ll.wv..ll.wv + d * d], &a[p * d..(p + 1) * d], &mut v[p * d..(p + 1) * d]);
            }
            // Causal attention per head.
            let mut att = vec![0.0; h * ctx * ctx];
            let mut o = vec![0.0; ctx * d];
            for hd in 0..h {
                let hoff = hd * dh;
                for p in 0..ctx {
                    let qrow = &q[p * d + hoff..p * d + hoff + dh];
                    // Scores j ≤ p.
                    let arow = &mut att[hd * ctx * ctx + p * ctx..hd * ctx * ctx + (p + 1) * ctx];
                    let mut maxs = f64::NEG_INFINITY;
                    for j in 0..=p {
                        let krow = &k[j * d + hoff..j * d + hoff + dh];
                        let mut s = 0.0;
                        for i in 0..dh {
                            s += qrow[i] * krow[i];
                        }
                        arow[j] = s * inv_sqrt_dh;
                        maxs = maxs.max(arow[j]);
                    }
                    let mut z = 0.0;
                    for j in 0..=p {
                        arow[j] = (arow[j] - maxs).exp();
                        z += arow[j];
                    }
                    for j in 0..=p {
                        arow[j] /= z;
                    }
                    // Mix values.
                    let orow = &mut o[p * d + hoff..p * d + hoff + dh];
                    for j in 0..=p {
                        let w = arow[j];
                        let vrow = &v[j * d + hoff..j * d + hoff + dh];
                        for i in 0..dh {
                            orow[i] += w * vrow[i];
                        }
                    }
                }
            }
            // Project + residual.
            let mut x_mid = x_in.clone();
            let mut tmp = vec![0.0; d];
            for p in 0..ctx {
                matvec_into(&params[ll.wo..ll.wo + d * d], &o[p * d..(p + 1) * d], &mut tmp);
                for i in 0..d {
                    x_mid[p * d + i] += tmp[i];
                }
            }
            // LN2 + MLP + residual.
            let mut bmat = vec![0.0; ctx * d];
            let mut ln2_mu = vec![0.0; ctx];
            let mut ln2_rstd = vec![0.0; ctx];
            let g2 = &params[ll.ln2_g..ll.ln2_g + d];
            let b2v = &params[ll.ln2_b..ll.ln2_b + d];
            for p in 0..ctx {
                let (mu, rstd) =
                    ln_forward(&x_mid[p * d..(p + 1) * d], g2, b2v, &mut bmat[p * d..(p + 1) * d]);
                ln2_mu[p] = mu;
                ln2_rstd[p] = rstd;
            }
            let mut u = vec![0.0; ctx * f];
            let mut gbuf = vec![0.0; ctx * f];
            let mut x_out = x_mid.clone();
            let b1p = &params[ll.b1..ll.b1 + f];
            let b2p = &params[ll.b2..ll.b2 + d];
            let mut mlp_out = vec![0.0; d];
            for p in 0..ctx {
                matvec_into(&params[ll.w1..ll.w1 + f * d], &bmat[p * d..(p + 1) * d], &mut u[p * f..(p + 1) * f]);
                for i in 0..f {
                    u[p * f + i] += b1p[i];
                    gbuf[p * f + i] = gelu(u[p * f + i]);
                }
                matvec_into(&params[ll.w2..ll.w2 + d * f], &gbuf[p * f..(p + 1) * f], &mut mlp_out);
                for i in 0..d {
                    x_out[p * d + i] += mlp_out[i] + b2p[i];
                }
            }
            layers.push(LayerCache {
                x_in,
                ln1_mu,
                ln1_rstd,
                a,
                q,
                k,
                v,
                att,
                o,
                x_mid,
                ln2_mu,
                ln2_rstd,
                bmat,
                u,
                g: gbuf,
            });
            x = x_out;
        }

        // Final LN at the last position + head.
        let last = ctx - 1;
        let mut f_last = vec![0.0; d];
        let (lnf_mu, lnf_rstd) = ln_forward(
            &x[last * d..(last + 1) * d],
            &params[self.layout.lnf_g..self.layout.lnf_g + d],
            &params[self.layout.lnf_b..self.layout.lnf_b + d],
            &mut f_last,
        );
        let mut logits = vec![0.0; c.vocab];
        matvec_into(&params[self.layout.head..self.layout.head + c.vocab * d], &f_last, &mut logits);
        ForwardCache { layers, x_final: x, lnf_mu, lnf_rstd, f_last, logits }
    }

    /// Backward for one sample: given `dlogits = ∂log p/∂logits`, write
    /// `∂log p/∂θ` into `out` (dense accumulate).
    fn backward(&self, params: &[f64], tokens: &[u32], cache: &ForwardCache, dlogits: &[f64], out: &mut [f64]) {
        let c = &self.config;
        let (d, h, f, ctx) = (c.dim, c.heads, c.mlp_hidden, c.context);
        let dh = d / h;
        let inv_sqrt_dh = 1.0 / (dh as f64).sqrt();
        let last = ctx - 1;

        // Head backward.
        let mut d_f = vec![0.0; d];
        {
            let head = &params[self.layout.head..self.layout.head + c.vocab * d];
            let dhead = &mut out[self.layout.head..self.layout.head + c.vocab * d];
            matvec_backward(head, &cache.f_last, dlogits, &mut d_f, dhead);
        }
        // Final LN backward (last position only).
        let mut dx = vec![0.0; ctx * d];
        {
            let x_last = &cache.x_final[last * d..(last + 1) * d];
            let g = &params[self.layout.lnf_g..self.layout.lnf_g + d];
            let (dg_range, db_range) = (
                self.layout.lnf_g..self.layout.lnf_g + d,
                self.layout.lnf_b..self.layout.lnf_b + d,
            );
            // Split-borrow dg/db out of `out`.
            let mut dgv = vec![0.0; d];
            let mut dbv = vec![0.0; d];
            let mut dxl = vec![0.0; d];
            ln_backward(x_last, g, cache.lnf_mu, cache.lnf_rstd, &d_f, &mut dgv, &mut dbv, &mut dxl);
            for (i, idx) in dg_range.enumerate() {
                out[idx] += dgv[i];
            }
            for (i, idx) in db_range.enumerate() {
                out[idx] += dbv[i];
            }
            for i in 0..d {
                dx[last * d + i] += dxl[i];
            }
        }

        // Blocks in reverse.
        for (li, ll) in self.layout.layers.iter().enumerate().rev() {
            let lc = &cache.layers[li];
            // ---- MLP backward ----
            let mut dx_mid = dx.clone(); // residual path
            for p in 0..ctx {
                let dxo = &dx[p * d..(p + 1) * d];
                if dxo.iter().all(|&v| v == 0.0) {
                    continue;
                }
                // b2 grad.
                for i in 0..d {
                    out[ll.b2 + i] += dxo[i];
                }
                // W2 backward.
                let mut d_g = vec![0.0; f];
                {
                    let w2 = &params[ll.w2..ll.w2 + d * f];
                    let dw2 = &mut out[ll.w2..ll.w2 + d * f];
                    matvec_backward(w2, &lc.g[p * f..(p + 1) * f], dxo, &mut d_g, dw2);
                }
                // GELU backward.
                let mut d_u = vec![0.0; f];
                for i in 0..f {
                    d_u[i] = d_g[i] * gelu_prime(lc.u[p * f + i]);
                }
                // b1 grad + W1 backward.
                for i in 0..f {
                    out[ll.b1 + i] += d_u[i];
                }
                let mut d_b = vec![0.0; d];
                {
                    let w1 = &params[ll.w1..ll.w1 + f * d];
                    let dw1 = &mut out[ll.w1..ll.w1 + f * d];
                    matvec_backward(w1, &lc.bmat[p * d..(p + 1) * d], &d_u, &mut d_b, dw1);
                }
                // LN2 backward.
                let mut dgv = vec![0.0; d];
                let mut dbv = vec![0.0; d];
                let mut dxm = vec![0.0; d];
                ln_backward(
                    &lc.x_mid[p * d..(p + 1) * d],
                    &params[ll.ln2_g..ll.ln2_g + d],
                    lc.ln2_mu[p],
                    lc.ln2_rstd[p],
                    &d_b,
                    &mut dgv,
                    &mut dbv,
                    &mut dxm,
                );
                for i in 0..d {
                    out[ll.ln2_g + i] += dgv[i];
                    out[ll.ln2_b + i] += dbv[i];
                    dx_mid[p * d + i] += dxm[i];
                }
            }

            // ---- Attention backward ----
            let mut dx_in = dx_mid.clone(); // residual path
            let mut d_o = vec![0.0; ctx * d];
            for p in 0..ctx {
                let dxm = &dx_mid[p * d..(p + 1) * d];
                if dxm.iter().all(|&v| v == 0.0) {
                    continue;
                }
                let wo = &params[ll.wo..ll.wo + d * d];
                let dwo = &mut out[ll.wo..ll.wo + d * d];
                let mut dop = vec![0.0; d];
                matvec_backward(wo, &lc.o[p * d..(p + 1) * d], dxm, &mut dop, dwo);
                for i in 0..d {
                    d_o[p * d + i] += dop[i];
                }
            }
            let mut d_q = vec![0.0; ctx * d];
            let mut d_k = vec![0.0; ctx * d];
            let mut d_v = vec![0.0; ctx * d];
            for hd in 0..h {
                let hoff = hd * dh;
                for p in 0..ctx {
                    let dorow = &d_o[p * d + hoff..p * d + hoff + dh];
                    if dorow.iter().all(|&v| v == 0.0) {
                        continue;
                    }
                    let arow = &lc.att[hd * ctx * ctx + p * ctx..hd * ctx * ctx + (p + 1) * ctx];
                    // datt and dv.
                    let mut datt = vec![0.0; p + 1];
                    for j in 0..=p {
                        let vrow = &lc.v[j * d + hoff..j * d + hoff + dh];
                        let mut s = 0.0;
                        for i in 0..dh {
                            s += dorow[i] * vrow[i];
                        }
                        datt[j] = s;
                        let w = arow[j];
                        let dvrow = &mut d_v[j * d + hoff..j * d + hoff + dh];
                        for i in 0..dh {
                            dvrow[i] += w * dorow[i];
                        }
                    }
                    // Softmax backward.
                    let dot: f64 = (0..=p).map(|j| arow[j] * datt[j]).sum();
                    for j in 0..=p {
                        let dscore = arow[j] * (datt[j] - dot) * inv_sqrt_dh;
                        if dscore == 0.0 {
                            continue;
                        }
                        let krow = &lc.k[j * d + hoff..j * d + hoff + dh];
                        let qrow = &lc.q[p * d + hoff..p * d + hoff + dh];
                        let dqrow = &mut d_q[p * d + hoff..p * d + hoff + dh];
                        for i in 0..dh {
                            dqrow[i] += dscore * krow[i];
                        }
                        let dkrow = &mut d_k[j * d + hoff..j * d + hoff + dh];
                        for i in 0..dh {
                            dkrow[i] += dscore * qrow[i];
                        }
                    }
                }
            }
            // QKV weight backward + d_a.
            let mut d_a = vec![0.0; ctx * d];
            for p in 0..ctx {
                let arow = &lc.a[p * d..(p + 1) * d];
                let da = &mut d_a[p * d..(p + 1) * d];
                {
                    let w = &params[ll.wq..ll.wq + d * d];
                    let dw = &mut out[ll.wq..ll.wq + d * d];
                    matvec_backward(w, arow, &d_q[p * d..(p + 1) * d], da, dw);
                }
                {
                    let w = &params[ll.wk..ll.wk + d * d];
                    let dw = &mut out[ll.wk..ll.wk + d * d];
                    matvec_backward(w, arow, &d_k[p * d..(p + 1) * d], da, dw);
                }
                {
                    let w = &params[ll.wv..ll.wv + d * d];
                    let dw = &mut out[ll.wv..ll.wv + d * d];
                    matvec_backward(w, arow, &d_v[p * d..(p + 1) * d], da, dw);
                }
            }
            // LN1 backward.
            for p in 0..ctx {
                let da = &d_a[p * d..(p + 1) * d];
                if da.iter().all(|&v| v == 0.0) {
                    continue;
                }
                let mut dgv = vec![0.0; d];
                let mut dbv = vec![0.0; d];
                let mut dxi = vec![0.0; d];
                ln_backward(
                    &lc.x_in[p * d..(p + 1) * d],
                    &params[ll.ln1_g..ll.ln1_g + d],
                    lc.ln1_mu[p],
                    lc.ln1_rstd[p],
                    da,
                    &mut dgv,
                    &mut dbv,
                    &mut dxi,
                );
                for i in 0..d {
                    out[ll.ln1_g + i] += dgv[i];
                    out[ll.ln1_b + i] += dbv[i];
                    dx_in[p * d + i] += dxi[i];
                }
            }
            dx = dx_in;
        }

        // Embedding backward.
        for p in 0..ctx {
            let t = tokens[p] as usize;
            for i in 0..d {
                let g = dx[p * d + i];
                out[self.layout.wte + t * d + i] += g;
                out[self.layout.wpe + p * d + i] += g;
            }
        }
    }

    /// Next-token log-probabilities for one context (inference).
    pub fn log_probs(&self, params: &[f64], tokens: &[u32]) -> Vec<f64> {
        let cache = self.forward(params, tokens);
        let maxl = cache.logits.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let z: f64 = cache.logits.iter().map(|l| (l - maxl).exp()).sum();
        let logz = maxl + z.ln();
        cache.logits.iter().map(|l| l - logz).collect()
    }

    /// Evaluate a batch of `(context, target)` pairs: mean NLL, gradient,
    /// and the 1/√n-scaled score matrix.
    pub fn batch_eval(&self, params: &[f64], contexts: &[Vec<u32>], targets: &[u32]) -> BatchEval {
        let n = contexts.len();
        assert_eq!(targets.len(), n);
        let m = self.num_params();
        let inv_sqrt_n = 1.0 / (n as f64).sqrt();
        let mut scores = Mat::zeros(n, m);
        let mut loss = 0.0;
        for i in 0..n {
            let cache = self.forward(params, &contexts[i]);
            let y = targets[i] as usize;
            let maxl = cache.logits.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            let z: f64 = cache.logits.iter().map(|l| (l - maxl).exp()).sum();
            let logz = maxl + z.ln();
            loss -= cache.logits[y] - logz;
            // ∂log p_y/∂logits = e_y − softmax.
            let mut d: Vec<f64> = cache
                .logits
                .iter()
                .map(|l| -((l - maxl).exp() / z))
                .collect();
            d[y] += 1.0;
            self.backward(params, &contexts[i], &cache, &d, scores.row_mut(i));
            // Scale the row by 1/√n (paper's S definition).
            for sv in scores.row_mut(i) {
                *sv *= inv_sqrt_n;
            }
        }
        loss /= n as f64;
        let grad = super::grad_from_scores(&scores);
        BatchEval { loss, grad, scores }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> (Transformer, Vec<f64>) {
        let cfg = TransformerConfig {
            vocab: 7,
            dim: 8,
            heads: 2,
            layers: 2,
            context: 5,
            mlp_hidden: 12,
        };
        let model = Transformer::new(cfg);
        let params = model.init_params(&mut Rng::seed_from(230));
        (model, params)
    }

    #[test]
    fn log_probs_normalized() {
        let (model, params) = tiny();
        let lp = model.log_probs(&params, &[0, 1, 2, 3, 4]);
        let total: f64 = lp.iter().map(|l| l.exp()).sum();
        assert!((total - 1.0).abs() < 1e-12);
    }

    #[test]
    fn gradient_matches_finite_differences() {
        let (model, params) = tiny();
        let contexts = vec![vec![0u32, 1, 2, 3, 4], vec![4, 3, 2, 1, 0], vec![1, 1, 5, 6, 2]];
        let targets = vec![5u32, 6, 0];
        let eval = model.batch_eval(&params, &contexts, &targets);
        // Spot-check a spread of parameter indices (full FD would be slow).
        let m = model.num_params();
        let eps = 1e-5;
        let mut p = params.clone();
        let idxs: Vec<usize> =
            (0..37).map(|k| (k * 977) % m).collect::<std::collections::BTreeSet<_>>().into_iter().collect();
        for j in idxs {
            p[j] = params[j] + eps;
            let lp = model.batch_eval(&p, &contexts, &targets).loss;
            p[j] = params[j] - eps;
            let lm = model.batch_eval(&p, &contexts, &targets).loss;
            p[j] = params[j];
            let fd = (lp - lm) / (2.0 * eps);
            assert!(
                (eval.grad[j] - fd).abs() < 1e-6 * (1.0 + fd.abs()),
                "param {j}: analytic {} vs fd {fd}",
                eval.grad[j]
            );
        }
    }

    #[test]
    fn score_rows_are_per_sample_gradients() {
        let (model, params) = tiny();
        let contexts = vec![vec![0u32, 1, 2, 3, 4], vec![2, 2, 2, 2, 2]];
        let targets = vec![3u32, 1];
        let eval = model.batch_eval(&params, &contexts, &targets);
        // Single-sample batch: score row × √1 = ∂log p = −grad.
        for i in 0..2 {
            let single = model.batch_eval(&params, &contexts[i..i + 1].to_vec(), &targets[i..i + 1]);
            let sqrt2 = 2f64.sqrt();
            for j in (0..model.num_params()).step_by(53) {
                assert!(
                    (eval.scores[(i, j)] * sqrt2 + single.grad[j]).abs() < 1e-10,
                    "sample {i} param {j}"
                );
            }
        }
    }

    #[test]
    fn causality_later_tokens_do_not_affect_earlier_predictions() {
        // Changing the *last* token must not change log-probs computed
        // from a context whose prediction point is earlier. We test by
        // comparing the hidden path: predict from [a,b,c,d,X] — the
        // prediction reads position 4, so changing token 0..3 matters,
        // but a model with causal masking must give identical attention
        // rows for positions < 4 regardless of X. Here we verify the
        // practical contract: logits depend on X only through position 4.
        let (model, params) = tiny();
        let lp1 = model.log_probs(&params, &[0, 1, 2, 3, 4]);
        let lp2 = model.log_probs(&params, &[0, 1, 2, 3, 5]);
        // They *should* differ (X feeds position 4 itself)…
        let diff: f64 = lp1.iter().zip(&lp2).map(|(a, b)| (a - b).abs()).sum();
        assert!(diff > 1e-12);
        // …but changing a *padding-like* prefix token affects things too;
        // true causality is structural: attention rows only cover j ≤ p.
        // That is asserted directly on the forward cache:
        let cache = model.forward(&params, &[0, 1, 2, 3, 4]);
        let ctx = model.config.context;
        for hd in 0..model.config.heads {
            for p in 0..ctx {
                for j in p + 1..ctx {
                    assert_eq!(cache.layers[0].att[hd * ctx * ctx + p * ctx + j], 0.0);
                }
            }
        }
    }

    #[test]
    fn ngd_training_step_descends() {
        let (model, mut params) = tiny();
        let contexts: Vec<Vec<u32>> = (0..8)
            .map(|i| (0..5).map(|p| ((i + p) % 7) as u32).collect())
            .collect();
        let targets: Vec<u32> = (0..8).map(|i| ((i + 5) % 7) as u32).collect();
        let e0 = model.batch_eval(&params, &contexts, &targets);
        let mut opt = crate::ngd::NaturalGradient::new(
            Box::new(crate::solver::CholSolver::default()),
            crate::ngd::DampingSchedule::Constant { lambda: 1e-2 },
            0.5,
        );
        let mut loss = e0.loss;
        for _ in 0..10 {
            let e = model.batch_eval(&params, &contexts, &targets);
            loss = e.loss;
            opt.step(&mut params, &e.scores, &e.grad, e.loss).unwrap();
        }
        let efinal = model.batch_eval(&params, &contexts, &targets);
        assert!(efinal.loss < e0.loss, "{} → {}", e0.loss, efinal.loss);
        let _ = loss;
    }

    #[test]
    fn param_count_matches_layout() {
        let (model, params) = tiny();
        let c = &model.config;
        let per_layer = 2 * c.dim // ln1
            + 4 * c.dim * c.dim // qkvo
            + 2 * c.dim // ln2
            + c.mlp_hidden * c.dim + c.mlp_hidden // w1 b1
            + c.dim * c.mlp_hidden + c.dim; // w2 b2
        let expect = c.vocab * c.dim // wte
            + c.context * c.dim // wpe
            + c.layers * per_layer
            + 2 * c.dim // lnf
            + c.vocab * c.dim; // head
        assert_eq!(model.num_params(), expect);
        assert_eq!(params.len(), expect);
    }

    #[test]
    fn rejects_invalid_config() {
        let cfg = TransformerConfig { vocab: 5, dim: 6, heads: 4, layers: 1, context: 4, mlp_hidden: 8 };
        assert!(cfg.validate().is_err());
    }
}
