//! # dngd — Efficient Damped Natural Gradient Descent at Scale
//!
//! A production-oriented reproduction of *"Efficient Numerical Algorithm for
//! Large-Scale Damped Natural Gradient Descent"* (Chen, Xie & Wang, 2023).
//!
//! The paper's contribution is **Algorithm 1**: to solve the damped Fisher
//! system
//!
//! ```text
//! (SᵀS + λI) x = v,      S ∈ ℝ^{n×m},  m ≫ n
//! ```
//!
//! compute the *small* n×n Gram matrix `W = SSᵀ + λĨ`, Cholesky-factor it
//! `W = LLᵀ`, and recover `x = (v − SᵀL⁻ᵀL⁻¹Sv)/λ` with two triangular
//! solves — O(n³ + n²m) time and O(nm) memory instead of O(m³) / O(m²).
//!
//! ## Crate layout
//!
//! | module | role |
//! |--------|------|
//! | [`linalg`] | dense linear-algebra substrate (GEMM, SYRK, Cholesky, triangular solves, Jacobi eigh/SVD, QR, complex) — built from scratch, with runtime-dispatched AVX2/AVX-512/NEON micro-kernels and zero-allocation packing arenas |
//! | [`solver`] | the paper's Algorithm 1 (`chol`) and every baseline it benchmarks against (`eigh`, `svda`, `naive`, `cg`, `rvb`), behind the plan/factor/solve session API (Gram cached across λ-resweeps, blocked multi-RHS), plus complex SR variants |
//! | [`ngd`]    | natural-gradient optimizer: damping schedules, trust region, momentum, KFAC block-diagonal baseline |
//! | [`model`]  | native model substrate: MLP / tiny transformer with per-sample score rows |
//! | [`vmc`]    | variational Monte Carlo: Ising Hamiltonian, complex RBM, Metropolis, stochastic reconfiguration |
//! | [`data`]   | deterministic RNG, synthetic corpora, task generators, batching |
//! | [`runtime`]| PJRT runtime: load AOT HLO artifacts produced by `python/compile/aot.py` |
//! | [`coordinator`] | leader/worker sharded training runtime (m-axis sharding of S, tree reduce of the Gram matrix) |
//! | [`serve`]  | multi-tenant serving front-end: session cache, cross-tenant RHS coalescing, cost-model admission, pluggable shard transport (in-process channels / Unix sockets) |
//! | [`config`] | TOML config parser + typed configs + CLI merging |
//! | [`metrics`]| timers, counters, histograms, power-law fits, CSV sinks |
//! | [`checkpoint`] | binary checkpoint save/load |
//!
//! ## Quickstart
//!
//! ```rust
//! use dngd::data::rng::Rng;
//! use dngd::linalg::Mat;
//! use dngd::solver::{DampedSolver, CholSolver};
//!
//! let mut rng = Rng::seed_from(42);
//! let (n, m) = (32, 512);
//! let s = Mat::randn(n, m, &mut rng);
//! let v: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
//! let x = CholSolver::default().solve(&s, &v, 1e-3).unwrap();
//! // x satisfies (SᵀS + λI) x = v
//! let mut resid = s.t_matvec(&s.matvec(&x));
//! for j in 0..m { resid[j] += 1e-3 * x[j] - v[j]; }
//! assert!(resid.iter().all(|r| r.abs() < 1e-8));
//! ```

pub mod bench_tables;
pub mod checkpoint;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod linalg;
pub mod metrics;
pub mod model;
pub mod ngd;
pub mod runtime;
pub mod serve;
pub mod solver;
pub mod vmc;
