//! Minimal TOML-subset parser (from scratch; the build is offline).
//!
//! Supported: `[section]` headers, `key = value` with string, integer,
//! float, boolean and homogeneous-array values, `#` comments, blank
//! lines. This covers every config in `configs/`; anything else is a
//! parse error, not silent misbehaviour.

use std::collections::BTreeMap;

/// A parsed TOML value.
#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    String(String),
    Integer(i64),
    Float(f64),
    Boolean(bool),
    Array(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::String(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_int(&self) -> Option<i64> {
        match self {
            TomlValue::Integer(i) => Some(*i),
            _ => None,
        }
    }

    /// Floats accept integer literals too (`lambda = 1` means 1.0).
    pub fn as_float(&self) -> Option<f64> {
        match self {
            TomlValue::Float(f) => Some(*f),
            TomlValue::Integer(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Boolean(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[TomlValue]> {
        match self {
            TomlValue::Array(a) => Some(a),
            _ => None,
        }
    }
}

/// Parse error with line information.
#[derive(Debug, Clone, PartialEq)]
pub struct TomlError {
    pub line: usize,
    pub message: String,
}

impl std::fmt::Display for TomlError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "config parse error at line {}: {}", self.line, self.message)
    }
}

impl std::error::Error for TomlError {}

/// Parsed document: `section.key → value`. Top-level keys use section "".
pub type TomlDoc = BTreeMap<String, TomlValue>;

/// Parse a TOML-subset document into a flat `section.key` map.
pub fn parse_toml(text: &str) -> Result<TomlDoc, TomlError> {
    let mut doc = TomlDoc::new();
    let mut section = String::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        let err = |message: String| TomlError { line: lineno + 1, message };
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest
                .strip_suffix(']')
                .ok_or_else(|| err("unterminated section header".into()))?
                .trim();
            if name.is_empty() || !name.chars().all(|c| c.is_alphanumeric() || c == '_' || c == '.')
            {
                return Err(err(format!("invalid section name {name:?}")));
            }
            section = name.to_string();
            continue;
        }
        let eq = line
            .find('=')
            .ok_or_else(|| err(format!("expected `key = value`, got {line:?}")))?;
        let key = line[..eq].trim();
        if key.is_empty() || !key.chars().all(|c| c.is_alphanumeric() || c == '_') {
            return Err(err(format!("invalid key {key:?}")));
        }
        let value = parse_value(line[eq + 1..].trim()).map_err(&err)?;
        let full = if section.is_empty() { key.to_string() } else { format!("{section}.{key}") };
        if doc.insert(full.clone(), value).is_some() {
            return Err(err(format!("duplicate key {full:?}")));
        }
    }
    Ok(doc)
}

fn strip_comment(line: &str) -> &str {
    // `#` starts a comment unless inside a quoted string.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

pub(crate) fn parse_value(s: &str) -> Result<TomlValue, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(rest) = s.strip_prefix('"') {
        let inner = rest.strip_suffix('"').ok_or("unterminated string")?;
        if inner.contains('"') {
            return Err("embedded quote in string".into());
        }
        return Ok(TomlValue::String(inner.to_string()));
    }
    if s == "true" {
        return Ok(TomlValue::Boolean(true));
    }
    if s == "false" {
        return Ok(TomlValue::Boolean(false));
    }
    if let Some(rest) = s.strip_prefix('[') {
        let inner = rest.strip_suffix(']').ok_or("unterminated array")?.trim();
        if inner.is_empty() {
            return Ok(TomlValue::Array(vec![]));
        }
        let items: Result<Vec<TomlValue>, String> =
            inner.split(',').map(|it| parse_value(it.trim())).collect();
        return Ok(TomlValue::Array(items?));
    }
    // Number: integer unless it has . e E.
    let is_floaty = s.contains('.') || s.contains('e') || s.contains('E');
    if !is_floaty {
        if let Ok(i) = s.replace('_', "").parse::<i64>() {
            return Ok(TomlValue::Integer(i));
        }
    }
    if let Ok(f) = s.replace('_', "").parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    Err(format!("cannot parse value {s:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_scalar_types() {
        let doc = parse_toml(
            r#"
# top comment
name = "dngd"       # inline comment
steps = 100
lr = 1e-2
debug = false

[solver]
kind = "chol"
lambda = 0.001
threads = 4
sizes = [256, 512, 1024]
"#,
        )
        .unwrap();
        assert_eq!(doc["name"], TomlValue::String("dngd".into()));
        assert_eq!(doc["steps"], TomlValue::Integer(100));
        assert_eq!(doc["lr"], TomlValue::Float(0.01));
        assert_eq!(doc["debug"], TomlValue::Boolean(false));
        assert_eq!(doc["solver.kind"], TomlValue::String("chol".into()));
        assert_eq!(doc["solver.lambda"].as_float(), Some(0.001));
        assert_eq!(
            doc["solver.sizes"],
            TomlValue::Array(vec![
                TomlValue::Integer(256),
                TomlValue::Integer(512),
                TomlValue::Integer(1024)
            ])
        );
    }

    #[test]
    fn integer_accepted_as_float() {
        let doc = parse_toml("lambda = 1").unwrap();
        assert_eq!(doc["lambda"].as_float(), Some(1.0));
    }

    #[test]
    fn hash_inside_string_not_comment() {
        let doc = parse_toml(r##"tag = "a#b""##).unwrap();
        assert_eq!(doc["tag"].as_str(), Some("a#b"));
    }

    #[test]
    fn errors_carry_line_numbers() {
        let err = parse_toml("ok = 1\nbroken line\n").unwrap_err();
        assert_eq!(err.line, 2);
        let err = parse_toml("[unclosed\n").unwrap_err();
        assert_eq!(err.line, 1);
        let err = parse_toml("a = 1\na = 2\n").unwrap_err();
        assert!(err.message.contains("duplicate"));
    }

    #[test]
    fn rejects_bad_values() {
        assert!(parse_toml("x = @nope").is_err());
        assert!(parse_toml(r#"x = "unterminated"#).is_err());
        assert!(parse_toml("x = [1, 2").is_err());
    }

    #[test]
    fn underscore_separators_in_numbers() {
        let doc = parse_toml("m = 100_000").unwrap();
        assert_eq!(doc["m"].as_int(), Some(100000));
    }
}
