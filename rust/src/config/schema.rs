//! Typed configuration schema with validation and CLI overrides.

use super::toml::{parse_toml, parse_value, TomlDoc};
use crate::linalg::KernelIsa;
use crate::solver::{BlockKind, Precision, SolverKind, SolverOptions};

/// Solver selection + damping + per-solver options.
#[derive(Debug, Clone, PartialEq)]
pub struct SolverConfig {
    pub kind: SolverKind,
    pub lambda: f64,
    /// λ decay factor per step (1.0 = constant).
    pub lambda_decay: f64,
    pub lambda_min: f64,
    pub lambda_max: f64,
    /// Levenberg–Marquardt adaptive damping: shrink λ on improvement,
    /// grow on regression (overrides lambda_decay). Stabilizes
    /// mini-batch NGD, where n ≪ m makes the per-batch Fisher noisy.
    pub adaptive: bool,
    pub threads: usize,
    /// ISA tier override for the dense kernels (`[solver] isa =
    /// "scalar"|"avx2"|"avx512"|"neon"|"auto"`, PR 4). `None`/`auto`
    /// dispatches on the process tier (CPUID / `DNGD_KERNEL`).
    pub isa: Option<KernelIsa>,
    /// CG relative-residual tolerance (`--set solver.cg_tol=…`).
    pub cg_tol: f64,
    /// CG iteration cap.
    pub cg_max_iters: usize,
    /// Accept capped CG solves within 100×cg_tol true residual
    /// (`solver.cg_loose_accept`; default false — PR-5 made the old
    /// silent leniency an explicit opt-in).
    pub cg_loose_accept: bool,
    /// Modeled device budget in GB for svda/naive (0 = 80 GB A100).
    pub budget_gb: f64,
    /// RVB `v = Sᵀf` reconstruction tolerance.
    pub rvb_tol: f64,
    /// Sliding-window size for streaming NGD (`[solver] window = W`,
    /// PR 5; 0 = classic per-batch Fisher). Must exceed
    /// `train.batch_size` so successive batches overlap in the window.
    pub window: usize,
    /// Rotations between full streaming refactors (drift backstop;
    /// 0 = never).
    pub refresh_every: usize,
    /// Kernel precision mode (`[solver] precision = "f64"|"mixed"`,
    /// PR 6). `mixed` factors the Gram in f32 and recovers f64 accuracy
    /// by iterative refinement; only `chol`/`rvb` support it —
    /// validation rejects the combination for every other kind.
    pub precision: Precision,
    /// Mixed-mode relative true-residual target per right-hand side.
    pub tol: f64,
    /// Uniform block count for the structured kinds (`[solver] blocks`,
    /// PR 10). 0 = one block. Only meaningful for
    /// `blockdiag`/`kpsvd`/`hybrid`; cross-checked in
    /// [`Config::validate`].
    pub blocks: usize,
    /// Inner per-block session kind for `blockdiag`/`hybrid`
    /// (`"auto"|"chol"|"rvb"`; auto = cost-model pick per block).
    pub block_kind: BlockKind,
    /// Hybrid PCG relative true-residual tolerance
    /// (`solver.hybrid_tol`).
    pub hybrid_tol: f64,
}

impl Default for SolverConfig {
    fn default() -> Self {
        let opts = SolverOptions::default();
        SolverConfig {
            kind: SolverKind::Chol,
            lambda: 1e-3,
            lambda_decay: 1.0,
            lambda_min: 1e-6,
            lambda_max: 1e3,
            adaptive: false,
            threads: opts.threads,
            isa: opts.isa,
            cg_tol: opts.cg_tol,
            cg_max_iters: opts.cg_max_iters,
            cg_loose_accept: opts.cg_loose_accept,
            budget_gb: opts.budget_gb,
            rvb_tol: opts.rvb_tol,
            window: opts.window,
            refresh_every: opts.refresh_every,
            precision: opts.precision,
            tol: opts.tol,
            blocks: opts.blocks,
            block_kind: opts.block_kind,
            hybrid_tol: opts.hybrid_tol,
        }
    }
}

impl SolverConfig {
    /// The per-solver options this config selects — handed to
    /// [`crate::solver::SolverRegistry`] by the trainer and CLI.
    pub fn options(&self) -> SolverOptions {
        SolverOptions {
            threads: self.threads.max(1),
            isa: self.isa,
            cg_tol: self.cg_tol,
            cg_max_iters: self.cg_max_iters,
            cg_loose_accept: self.cg_loose_accept,
            budget_gb: self.budget_gb,
            rvb_tol: self.rvb_tol,
            window: self.window,
            refresh_every: self.refresh_every,
            precision: self.precision,
            tol: self.tol,
            blocks: self.blocks,
            block_kind: self.block_kind,
            hybrid_tol: self.hybrid_tol,
        }
    }
}

/// Transformer-LM model shape.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelConfig {
    pub dim: usize,
    pub heads: usize,
    pub layers: usize,
    pub context: usize,
    pub mlp_hidden: usize,
}

impl Default for ModelConfig {
    fn default() -> Self {
        ModelConfig { dim: 16, heads: 2, layers: 2, context: 16, mlp_hidden: 64 }
    }
}

/// Training-loop settings.
#[derive(Debug, Clone, PartialEq)]
pub struct TrainConfig {
    pub steps: usize,
    pub batch_size: usize,
    pub learning_rate: f64,
    pub momentum: f64,
    pub trust_radius: f64,
    pub corpus_len: usize,
    pub seed: u64,
    pub log_every: usize,
    pub checkpoint_every: usize,
    pub checkpoint_dir: String,
    /// Numerical-health sentinel (PR 9): NaN/Inf guards, loss-divergence
    /// and λ-runaway detection with rollback to the last good checkpoint.
    pub sentinel: bool,
    /// Divergence trip: loss > ratio × best-loss-so-far for
    /// `divergence_patience` consecutive steps.
    pub divergence_ratio: f64,
    /// Consecutive bad steps before the divergence / λ-runaway sentinels
    /// trip (hysteresis — one noisy mini-batch must not roll back).
    pub divergence_patience: usize,
    /// Rollback-with-λ-escalation attempts before the run aborts with a
    /// typed error.
    pub max_rollbacks: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            steps: 200,
            batch_size: 64,
            learning_rate: 0.2,
            momentum: 0.0,
            trust_radius: 0.0, // 0 = disabled
            corpus_len: 100_000,
            seed: 42,
            log_every: 10,
            checkpoint_every: 0, // 0 = disabled
            checkpoint_dir: "checkpoints".into(),
            sentinel: true,
            divergence_ratio: 4.0,
            divergence_patience: 5,
            max_rollbacks: 3,
        }
    }
}

/// Coordinator topology.
#[derive(Debug, Clone, PartialEq)]
pub struct CoordinatorConfig {
    /// Worker count for the m-axis sharding of S.
    pub workers: usize,
    /// Bounded-channel depth (backpressure window).
    pub queue_depth: usize,
    /// Use the PJRT artifact runtime when an artifact matches the shape.
    pub use_artifacts: bool,
    pub artifact_dir: String,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            workers: 4,
            queue_depth: 4,
            use_artifacts: true,
            artifact_dir: "artifacts".into(),
        }
    }
}

/// VMC / stochastic-reconfiguration settings.
#[derive(Debug, Clone, PartialEq)]
pub struct VmcConfig {
    pub sites: usize,
    pub coupling_j: f64,
    pub field_h: f64,
    pub hidden: usize,
    pub samples: usize,
    pub iterations: usize,
    pub learning_rate: f64,
    pub seed: u64,
    /// "complex" or "real_part" (§3's two Fisher conventions).
    pub variant: String,
}

impl Default for VmcConfig {
    fn default() -> Self {
        VmcConfig {
            sites: 8,
            coupling_j: 1.0,
            field_h: 1.0,
            hidden: 16,
            samples: 400,
            iterations: 150,
            learning_rate: 0.08,
            seed: 7,
            variant: "complex".into(),
        }
    }
}

/// Serving front-end settings (PR 7) — consumed by
/// [`crate::serve::ServeOptions::from_config`], which also folds in the
/// `coordinator.*` shard topology and `solver.*` kernel knobs.
#[derive(Debug, Clone, PartialEq)]
pub struct ServeConfig {
    /// Concurrent tenant connection slots.
    pub tenants: usize,
    /// Dispatch-queue depth (must be ≥ tenants; cross-checked in
    /// [`Config::validate`]).
    pub queue_depth: usize,
    /// Gathering window per dispatch tick in ms (0 = dispatch
    /// immediately, the serial baseline).
    pub tick_ms: u64,
    /// Session-memory budget in GB under the `cost.rs` model
    /// (0 = the paper's 80 GB A100).
    pub budget_gb: f64,
    /// Shard worker transport: `"channels"` (in-process) or `"socket"`
    /// (out-of-process Unix-domain sockets).
    pub transport: String,
    /// Per-request deadline in ms: queueing + dispatch + retries +
    /// recovery, after which the request gets a typed
    /// `DeadlineExceeded` (PR 8).
    pub deadline_ms: u64,
    /// Cap on transient-fault retries per dispatched request.
    pub max_retries: u32,
    /// Session-record snapshot cadence: refresh the window snapshot and
    /// clear the rotation log every this many rotations.
    pub snapshot_every: usize,
    /// Worker supervision: probe + respawn dead workers and
    /// re-materialize their sessions. Off restores PR-7 behavior
    /// (fatal faults propagate as typed errors).
    pub supervise: bool,
    /// Directory for durable session records (empty = in-memory only).
    pub record_dir: String,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            tenants: 16,
            queue_depth: 64,
            tick_ms: 2,
            budget_gb: 0.0,
            transport: "channels".into(),
            deadline_ms: 5_000,
            max_retries: 4,
            snapshot_every: 16,
            supervise: true,
            record_dir: String::new(),
        }
    }
}

/// Chaos-harness settings (PR 8) — consumed by `dngd chaos`.
#[derive(Debug, Clone, PartialEq)]
pub struct ChaosConfig {
    /// What the harness attacks: `"serve"` (PR 8: worker faults under
    /// the serving layer) or `"train"` (PR 9: trainer kills at step
    /// boundaries + checkpoint corruption, asserting bit-identical
    /// resume).
    pub target: String,
    /// Fault schedule (serve target): `"all"` or one of the named
    /// schedules (`kill-during-factor`, `stall-during-panel`,
    /// `corrupt-frame`, `respawn-storm`).
    pub schedule: String,
    /// Workload seed (the chaos workload is fully deterministic).
    pub seed: u64,
    /// Solve requests per schedule run (serve target).
    pub requests: usize,
    /// Kill cadence for the respawn-storm schedule (serve target).
    pub kill_every: usize,
    /// Randomized kill points per train-chaos scenario (train target).
    pub kills: usize,
}

impl Default for ChaosConfig {
    fn default() -> Self {
        ChaosConfig {
            target: "serve".into(),
            schedule: "all".into(),
            seed: 4242,
            requests: 40,
            kill_every: 10,
            kills: 3,
        }
    }
}

/// Root configuration.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Config {
    pub solver: SolverConfig,
    pub model: ModelConfig,
    pub train: TrainConfig,
    pub coordinator: CoordinatorConfig,
    pub vmc: VmcConfig,
    pub serve: ServeConfig,
    pub chaos: ChaosConfig,
}

impl Config {
    /// Parse a TOML document + `section.key=value` overrides.
    pub fn from_toml_str(text: &str, overrides: &[String]) -> Result<Config, String> {
        let mut doc = parse_toml(text).map_err(|e| e.to_string())?;
        for ov in overrides {
            let eq = ov.find('=').ok_or_else(|| format!("override {ov:?} is not key=value"))?;
            let key = ov[..eq].trim().to_string();
            let value = parse_value(ov[eq + 1..].trim()).map_err(|e| format!("override {key}: {e}"))?;
            doc.insert(key, value);
        }
        Config::from_doc(&doc)
    }

    /// Load a config file (missing path = all defaults + overrides).
    pub fn load(path: Option<&str>, overrides: &[String]) -> Result<Config, String> {
        let text = match path {
            Some(p) => std::fs::read_to_string(p).map_err(|e| format!("read {p}: {e}"))?,
            None => String::new(),
        };
        Config::from_toml_str(&text, overrides)
    }

    fn from_doc(doc: &TomlDoc) -> Result<Config, String> {
        let mut cfg = Config::default();
        let known = |k: &str| -> bool {
            // Every key consumed below; used for unknown-key detection.
            KNOWN_KEYS.contains(&k)
        };
        for key in doc.keys() {
            if !known(key) {
                return Err(format!(
                    "unknown config key {key:?} (known keys: {})",
                    KNOWN_KEYS.join(", ")
                ));
            }
        }

        get_str(doc, "solver.kind", |s| {
            SolverKind::parse(s)
                .map(|k| cfg.solver.kind = k)
                .ok_or_else(|| format!("unknown solver kind {s:?}"))
        })?;
        get_f64(doc, "solver.lambda", &mut cfg.solver.lambda)?;
        get_f64(doc, "solver.lambda_decay", &mut cfg.solver.lambda_decay)?;
        get_f64(doc, "solver.lambda_min", &mut cfg.solver.lambda_min)?;
        get_f64(doc, "solver.lambda_max", &mut cfg.solver.lambda_max)?;
        get_bool(doc, "solver.adaptive", &mut cfg.solver.adaptive)?;
        get_usize(doc, "solver.threads", &mut cfg.solver.threads)?;
        get_str(doc, "solver.isa", |s| {
            // One parser/validator with the CLI `--set solver.isa` path.
            let mut opts = SolverOptions::default();
            opts.apply("isa", s)?;
            cfg.solver.isa = opts.isa;
            Ok(())
        })?;
        get_f64(doc, "solver.cg_tol", &mut cfg.solver.cg_tol)?;
        get_usize(doc, "solver.cg_max_iters", &mut cfg.solver.cg_max_iters)?;
        get_bool(doc, "solver.cg_loose_accept", &mut cfg.solver.cg_loose_accept)?;
        get_f64(doc, "solver.budget_gb", &mut cfg.solver.budget_gb)?;
        get_f64(doc, "solver.rvb_tol", &mut cfg.solver.rvb_tol)?;
        get_usize(doc, "solver.window", &mut cfg.solver.window)?;
        get_usize(doc, "solver.refresh_every", &mut cfg.solver.refresh_every)?;
        get_str(doc, "solver.precision", |s| {
            // One parser/validator with the CLI `--set solver.precision`
            // path (kind compatibility is cross-checked in validate()).
            let mut opts = SolverOptions::default();
            opts.apply("precision", s)?;
            cfg.solver.precision = opts.precision;
            Ok(())
        })?;
        get_f64(doc, "solver.tol", &mut cfg.solver.tol)?;
        get_usize(doc, "solver.blocks", &mut cfg.solver.blocks)?;
        get_str(doc, "solver.block_kind", |s| {
            // One parser with the CLI `--set solver.block_kind` path
            // (kind compatibility is cross-checked in validate()).
            let mut opts = SolverOptions::default();
            opts.apply("block_kind", s)?;
            cfg.solver.block_kind = opts.block_kind;
            Ok(())
        })?;
        get_f64(doc, "solver.hybrid_tol", &mut cfg.solver.hybrid_tol)?;

        get_usize(doc, "model.dim", &mut cfg.model.dim)?;
        get_usize(doc, "model.heads", &mut cfg.model.heads)?;
        get_usize(doc, "model.layers", &mut cfg.model.layers)?;
        get_usize(doc, "model.context", &mut cfg.model.context)?;
        get_usize(doc, "model.mlp_hidden", &mut cfg.model.mlp_hidden)?;

        get_usize(doc, "train.steps", &mut cfg.train.steps)?;
        get_usize(doc, "train.batch_size", &mut cfg.train.batch_size)?;
        get_f64(doc, "train.learning_rate", &mut cfg.train.learning_rate)?;
        get_f64(doc, "train.momentum", &mut cfg.train.momentum)?;
        get_f64(doc, "train.trust_radius", &mut cfg.train.trust_radius)?;
        get_usize(doc, "train.corpus_len", &mut cfg.train.corpus_len)?;
        get_u64(doc, "train.seed", &mut cfg.train.seed)?;
        get_usize(doc, "train.log_every", &mut cfg.train.log_every)?;
        get_usize(doc, "train.checkpoint_every", &mut cfg.train.checkpoint_every)?;
        get_string(doc, "train.checkpoint_dir", &mut cfg.train.checkpoint_dir)?;
        get_bool(doc, "train.sentinel", &mut cfg.train.sentinel)?;
        get_f64(doc, "train.divergence_ratio", &mut cfg.train.divergence_ratio)?;
        get_usize(doc, "train.divergence_patience", &mut cfg.train.divergence_patience)?;
        get_usize(doc, "train.max_rollbacks", &mut cfg.train.max_rollbacks)?;

        get_usize(doc, "coordinator.workers", &mut cfg.coordinator.workers)?;
        get_usize(doc, "coordinator.queue_depth", &mut cfg.coordinator.queue_depth)?;
        get_bool(doc, "coordinator.use_artifacts", &mut cfg.coordinator.use_artifacts)?;
        get_string(doc, "coordinator.artifact_dir", &mut cfg.coordinator.artifact_dir)?;

        get_usize(doc, "vmc.sites", &mut cfg.vmc.sites)?;
        get_f64(doc, "vmc.coupling_j", &mut cfg.vmc.coupling_j)?;
        get_f64(doc, "vmc.field_h", &mut cfg.vmc.field_h)?;
        get_usize(doc, "vmc.hidden", &mut cfg.vmc.hidden)?;
        get_usize(doc, "vmc.samples", &mut cfg.vmc.samples)?;
        get_usize(doc, "vmc.iterations", &mut cfg.vmc.iterations)?;
        get_f64(doc, "vmc.learning_rate", &mut cfg.vmc.learning_rate)?;
        get_u64(doc, "vmc.seed", &mut cfg.vmc.seed)?;
        get_string(doc, "vmc.variant", &mut cfg.vmc.variant)?;

        get_usize(doc, "serve.tenants", &mut cfg.serve.tenants)?;
        get_usize(doc, "serve.queue_depth", &mut cfg.serve.queue_depth)?;
        get_u64(doc, "serve.tick_ms", &mut cfg.serve.tick_ms)?;
        get_f64(doc, "serve.budget_gb", &mut cfg.serve.budget_gb)?;
        get_string(doc, "serve.transport", &mut cfg.serve.transport)?;
        get_u64(doc, "serve.deadline_ms", &mut cfg.serve.deadline_ms)?;
        let mut max_retries = u64::from(cfg.serve.max_retries);
        get_u64(doc, "serve.max_retries", &mut max_retries)?;
        cfg.serve.max_retries = u32::try_from(max_retries)
            .map_err(|_| format!("serve.max_retries ({max_retries}) is out of range"))?;
        get_usize(doc, "serve.snapshot_every", &mut cfg.serve.snapshot_every)?;
        get_bool(doc, "serve.supervise", &mut cfg.serve.supervise)?;
        get_string(doc, "serve.record_dir", &mut cfg.serve.record_dir)?;

        get_string(doc, "chaos.target", &mut cfg.chaos.target)?;
        get_string(doc, "chaos.schedule", &mut cfg.chaos.schedule)?;
        get_u64(doc, "chaos.seed", &mut cfg.chaos.seed)?;
        get_usize(doc, "chaos.requests", &mut cfg.chaos.requests)?;
        get_usize(doc, "chaos.kill_every", &mut cfg.chaos.kill_every)?;
        get_usize(doc, "chaos.kills", &mut cfg.chaos.kills)?;

        cfg.validate()?;
        Ok(cfg)
    }

    /// Cross-field validation.
    pub fn validate(&self) -> Result<(), String> {
        if self.solver.lambda <= 0.0 {
            return Err("solver.lambda must be > 0 (the damped system needs λ > 0)".into());
        }
        if !(0.0..=1.0).contains(&self.solver.lambda_decay) {
            return Err("solver.lambda_decay must be in (0, 1]".into());
        }
        // Per-solver option ranges: one source of truth with the CLI
        // `--set solver.*` path — including the precision/kind
        // compatibility check (mixed needs a chol/rvb session).
        self.solver.options().validate_for(self.solver.kind)?;
        // Structured-kind cross-checks (PR 10): block options are inert
        // on the dense kinds — requesting them there is a config mistake,
        // so it hard-errors instead of being silently ignored. Kept at
        // the schema level (not validate_for) so `dngd solve --solver
        // all` can still sweep every kind from one option set.
        let structured = matches!(
            self.solver.kind,
            SolverKind::BlockDiag | SolverKind::KpSvd | SolverKind::Hybrid
        );
        if self.solver.blocks > 0 && !structured {
            return Err(format!(
                "solver.blocks ({}) only applies to the structured kinds (blockdiag, kpsvd, \
                 hybrid), not {:?} — drop it or switch solver.kind",
                self.solver.blocks,
                self.solver.kind.as_str()
            ));
        }
        if self.solver.block_kind != BlockKind::Auto
            && !matches!(self.solver.kind, SolverKind::BlockDiag | SolverKind::Hybrid)
        {
            return Err(format!(
                "solver.block_kind ({}) selects the inner per-block session, which only \
                 blockdiag and hybrid have — not {:?}",
                self.solver.block_kind,
                self.solver.kind.as_str()
            ));
        }
        if self.solver.window > 0 && self.solver.window <= self.train.batch_size {
            return Err(format!(
                "solver.window ({}) must exceed train.batch_size ({}): a window no larger than \
                 one batch has no cross-step overlap to amortize — raise the window or disable \
                 streaming (window = 0)",
                self.solver.window, self.train.batch_size
            ));
        }
        if self.model.dim % self.model.heads != 0 {
            return Err(format!(
                "model.heads {} must divide model.dim {}",
                self.model.heads, self.model.dim
            ));
        }
        if self.train.batch_size == 0 || self.train.steps == 0 {
            return Err("train.batch_size and train.steps must be positive".into());
        }
        if self.coordinator.workers == 0 {
            return Err("coordinator.workers must be ≥ 1".into());
        }
        if self.coordinator.queue_depth == 0 {
            return Err("coordinator.queue_depth must be ≥ 1".into());
        }
        if self.vmc.variant != "complex" && self.vmc.variant != "real_part" {
            return Err(format!("vmc.variant must be \"complex\" or \"real_part\", got {:?}", self.vmc.variant));
        }
        // serve.* range + cross-checks, one source of truth with the
        // `dngd serve` path ([`crate::serve::ServeOptions::validate`],
        // which re-validates the merged options).
        if self.serve.tenants == 0 {
            return Err("serve.tenants must be ≥ 1".into());
        }
        if self.serve.queue_depth < self.serve.tenants {
            return Err(format!(
                "serve.queue_depth ({}) must be ≥ serve.tenants ({}): every connected tenant \
                 needs at least one queue slot or admission livelocks",
                self.serve.queue_depth, self.serve.tenants
            ));
        }
        if self.serve.tick_ms > 10_000 {
            return Err("serve.tick_ms must be ≤ 10000".into());
        }
        if !self.serve.budget_gb.is_finite() || self.serve.budget_gb < 0.0 {
            return Err("serve.budget_gb must be ≥ 0 (0 = the 80 GB A100 default)".into());
        }
        crate::serve::TransportKind::parse(&self.serve.transport)
            .map_err(|e| format!("serve.transport: {e}"))?;
        if self.serve.deadline_ms == 0 || self.serve.deadline_ms > 600_000 {
            return Err("serve.deadline_ms must be in 1..=600000".into());
        }
        if self.serve.snapshot_every == 0 {
            return Err("serve.snapshot_every must be ≥ 1".into());
        }
        // Sentinel thresholds (PR 9): ratio ≤ 1 would trip on any
        // non-monotone loss; patience 0 would trip before any evidence.
        if !self.train.divergence_ratio.is_finite() || self.train.divergence_ratio <= 1.0 {
            return Err("train.divergence_ratio must be a finite value > 1".into());
        }
        if self.train.divergence_patience == 0 {
            return Err("train.divergence_patience must be ≥ 1".into());
        }
        if self.chaos.target != "serve" && self.chaos.target != "train" {
            return Err(format!(
                "chaos.target must be \"serve\" or \"train\", got {:?}",
                self.chaos.target
            ));
        }
        if self.chaos.schedule != "all" {
            crate::serve::FaultSchedule::parse(&self.chaos.schedule)
                .map_err(|e| format!("chaos.schedule: {e}"))?;
        }
        if self.chaos.requests == 0 {
            return Err("chaos.requests must be ≥ 1".into());
        }
        if self.chaos.kill_every == 0 {
            return Err("chaos.kill_every must be ≥ 1".into());
        }
        if self.chaos.kills == 0 {
            return Err("chaos.kills must be ≥ 1".into());
        }
        Ok(())
    }
}

const KNOWN_KEYS: &[&str] = &[
    "solver.kind",
    "solver.lambda",
    "solver.lambda_decay",
    "solver.lambda_min",
    "solver.lambda_max",
    "solver.adaptive",
    "solver.threads",
    "solver.isa",
    "solver.cg_tol",
    "solver.cg_max_iters",
    "solver.cg_loose_accept",
    "solver.budget_gb",
    "solver.rvb_tol",
    "solver.window",
    "solver.refresh_every",
    "solver.precision",
    "solver.tol",
    "solver.blocks",
    "solver.block_kind",
    "solver.hybrid_tol",
    "model.dim",
    "model.heads",
    "model.layers",
    "model.context",
    "model.mlp_hidden",
    "train.steps",
    "train.batch_size",
    "train.learning_rate",
    "train.momentum",
    "train.trust_radius",
    "train.corpus_len",
    "train.seed",
    "train.log_every",
    "train.checkpoint_every",
    "train.checkpoint_dir",
    "train.sentinel",
    "train.divergence_ratio",
    "train.divergence_patience",
    "train.max_rollbacks",
    "coordinator.workers",
    "coordinator.queue_depth",
    "coordinator.use_artifacts",
    "coordinator.artifact_dir",
    "vmc.sites",
    "vmc.coupling_j",
    "vmc.field_h",
    "vmc.hidden",
    "vmc.samples",
    "vmc.iterations",
    "vmc.learning_rate",
    "vmc.seed",
    "vmc.variant",
    "serve.tenants",
    "serve.queue_depth",
    "serve.tick_ms",
    "serve.budget_gb",
    "serve.transport",
    "serve.deadline_ms",
    "serve.max_retries",
    "serve.snapshot_every",
    "serve.supervise",
    "serve.record_dir",
    "chaos.target",
    "chaos.schedule",
    "chaos.seed",
    "chaos.requests",
    "chaos.kill_every",
    "chaos.kills",
];

fn get_f64(doc: &TomlDoc, key: &str, out: &mut f64) -> Result<(), String> {
    if let Some(v) = doc.get(key) {
        *out = v.as_float().ok_or_else(|| format!("{key} must be a number"))?;
    }
    Ok(())
}

fn get_usize(doc: &TomlDoc, key: &str, out: &mut usize) -> Result<(), String> {
    if let Some(v) = doc.get(key) {
        let i = v.as_int().ok_or_else(|| format!("{key} must be an integer"))?;
        if i < 0 {
            return Err(format!("{key} must be non-negative"));
        }
        *out = i as usize;
    }
    Ok(())
}

fn get_u64(doc: &TomlDoc, key: &str, out: &mut u64) -> Result<(), String> {
    if let Some(v) = doc.get(key) {
        let i = v.as_int().ok_or_else(|| format!("{key} must be an integer"))?;
        if i < 0 {
            return Err(format!("{key} must be non-negative"));
        }
        *out = i as u64;
    }
    Ok(())
}

fn get_bool(doc: &TomlDoc, key: &str, out: &mut bool) -> Result<(), String> {
    if let Some(v) = doc.get(key) {
        *out = v.as_bool().ok_or_else(|| format!("{key} must be a boolean"))?;
    }
    Ok(())
}

fn get_string(doc: &TomlDoc, key: &str, out: &mut String) -> Result<(), String> {
    if let Some(v) = doc.get(key) {
        *out = v.as_str().ok_or_else(|| format!("{key} must be a string"))?.to_string();
    }
    Ok(())
}

fn get_str(
    doc: &TomlDoc,
    key: &str,
    mut f: impl FnMut(&str) -> Result<(), String>,
) -> Result<(), String> {
    if let Some(v) = doc.get(key) {
        let s = v.as_str().ok_or_else(|| format!("{key} must be a string"))?;
        f(s)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_valid() {
        Config::default().validate().unwrap();
    }

    #[test]
    fn full_roundtrip() {
        let cfg = Config::from_toml_str(
            r#"
[solver]
kind = "eigh"
lambda = 0.01
threads = 8

[model]
dim = 32
heads = 4

[train]
steps = 500
learning_rate = 0.1

[coordinator]
workers = 8
use_artifacts = false

[vmc]
variant = "real_part"
"#,
            &[],
        )
        .unwrap();
        assert_eq!(cfg.solver.kind, SolverKind::Eigh);
        assert_eq!(cfg.solver.threads, 8);
        assert_eq!(cfg.model.dim, 32);
        assert_eq!(cfg.train.steps, 500);
        assert!(!cfg.coordinator.use_artifacts);
        assert_eq!(cfg.vmc.variant, "real_part");
        // untouched sections keep defaults
        assert_eq!(cfg.train.batch_size, TrainConfig::default().batch_size);
    }

    #[test]
    fn overrides_win() {
        let cfg = Config::from_toml_str(
            "[solver]\nlambda = 0.1\n",
            &["solver.lambda=0.5".into(), "train.steps=7".into()],
        )
        .unwrap();
        assert_eq!(cfg.solver.lambda, 0.5);
        assert_eq!(cfg.train.steps, 7);
    }

    #[test]
    fn unknown_keys_rejected() {
        let err = Config::from_toml_str("[solver]\nbogus = 1\n", &[]).unwrap_err();
        assert!(err.contains("unknown config key"));
    }

    #[test]
    fn validation_failures() {
        assert!(Config::from_toml_str("[solver]\nlambda = 0.0\n", &[]).is_err());
        assert!(Config::from_toml_str("[model]\ndim = 10\nheads = 3\n", &[]).is_err());
        assert!(Config::from_toml_str("[vmc]\nvariant = \"bogus\"\n", &[]).is_err());
        assert!(Config::from_toml_str("[solver]\nkind = \"lu\"\n", &[]).is_err());
        assert!(Config::from_toml_str("[solver]\ncg_tol = 0.0\n", &[]).is_err());
        assert!(Config::from_toml_str("[solver]\ncg_max_iters = 0\n", &[]).is_err());
    }

    #[test]
    fn per_solver_options_flow_through() {
        let cfg = Config::from_toml_str(
            "[solver]\nkind = \"cg\"\ncg_tol = 1e-8\ncg_max_iters = 321\n\
             cg_loose_accept = true\nbudget_gb = 40.0\n",
            &["solver.rvb_tol=1e-5".into()],
        )
        .unwrap();
        assert_eq!(cfg.solver.kind, SolverKind::Cg);
        let opts = cfg.solver.options();
        assert_eq!(opts.cg_tol, 1e-8);
        assert_eq!(opts.cg_max_iters, 321);
        assert!(opts.cg_loose_accept, "cg_loose_accept must reach the options");
        assert_eq!(opts.budget_gb, 40.0);
        assert_eq!(opts.rvb_tol, 1e-5);
        // …and default off (the strict PR-5 behaviour).
        assert!(!Config::from_toml_str("", &[]).unwrap().solver.cg_loose_accept);
        // rvb is parseable as a config kind (the PR-2 bug fix).
        let cfg = Config::from_toml_str("[solver]\nkind = \"rvb\"\n", &[]).unwrap();
        assert_eq!(cfg.solver.kind, SolverKind::Rvb);
    }

    #[test]
    fn streaming_window_keys_parse_and_cross_validate() {
        let cfg = Config::from_toml_str(
            "[solver]\nwindow = 256\nrefresh_every = 16\n\n[train]\nbatch_size = 64\n",
            &[],
        )
        .unwrap();
        assert_eq!(cfg.solver.window, 256);
        assert_eq!(cfg.solver.refresh_every, 16);
        assert_eq!(cfg.solver.options().window, 256);
        // Window must exceed the batch (no overlap otherwise).
        let err = Config::from_toml_str(
            "[solver]\nwindow = 64\n\n[train]\nbatch_size = 64\n",
            &[],
        )
        .unwrap_err();
        assert!(err.contains("solver.window"), "{err}");
        // window = 1 is rejected by the shared option validator.
        assert!(Config::from_toml_str("[solver]\nwindow = 1\n", &[]).is_err());
        // The --set path goes through the same keys.
        let cfg = Config::from_toml_str("", &["solver.window=128".into()]).unwrap();
        assert_eq!(cfg.solver.window, 128);
        // Defaults: streaming off, backstop at 64 rotations.
        let cfg = Config::from_toml_str("", &[]).unwrap();
        assert_eq!(cfg.solver.window, 0);
        assert_eq!(cfg.solver.refresh_every, 64);
    }

    #[test]
    fn solver_isa_parses_and_rejects_unknown_tiers() {
        // "scalar" is supported on every host; "auto" restores None.
        let cfg = Config::from_toml_str("[solver]\nisa = \"scalar\"\n", &[]).unwrap();
        assert_eq!(cfg.solver.isa, Some(KernelIsa::Scalar));
        assert_eq!(cfg.solver.options().isa, Some(KernelIsa::Scalar));
        let cfg = Config::from_toml_str("[solver]\nisa = \"auto\"\n", &[]).unwrap();
        assert_eq!(cfg.solver.isa, None);
        assert!(Config::from_toml_str("[solver]\nisa = \"sse9\"\n", &[]).is_err());
        // The --set override path goes through the same parser.
        let cfg = Config::from_toml_str("", &["solver.isa=scalar".into()]).unwrap();
        assert_eq!(cfg.solver.isa, Some(KernelIsa::Scalar));
    }

    #[test]
    fn solver_precision_parses_and_cross_validates_with_kind() {
        // Default: pure f64 on every kind.
        let cfg = Config::from_toml_str("", &[]).unwrap();
        assert_eq!(cfg.solver.precision, Precision::F64);
        assert_eq!(cfg.solver.tol, 1e-10);
        // mixed is accepted for the session kinds and flows to options.
        for kind in ["chol", "rvb"] {
            let cfg = Config::from_toml_str(
                &format!("[solver]\nkind = \"{kind}\"\nprecision = \"mixed\"\ntol = 1e-9\n"),
                &[],
            )
            .unwrap();
            assert_eq!(cfg.solver.precision, Precision::Mixed);
            assert_eq!(cfg.solver.options().precision, Precision::Mixed);
            assert_eq!(cfg.solver.options().tol, 1e-9);
        }
        // …and rejected with a clear error for every other kind.
        for kind in ["eigh", "svda", "naive", "cg"] {
            let err = Config::from_toml_str(
                &format!("[solver]\nkind = \"{kind}\"\nprecision = \"mixed\"\n"),
                &[],
            )
            .unwrap_err();
            assert!(err.contains("precision=mixed") && err.contains(kind), "{err}");
        }
        // Unknown modes and bad tolerances are hard errors.
        assert!(Config::from_toml_str("[solver]\nprecision = \"f16\"\n", &[]).is_err());
        assert!(Config::from_toml_str("[solver]\ntol = 0.0\n", &[]).is_err());
        // The --set override path goes through the same parser.
        let cfg = Config::from_toml_str("", &["solver.precision=mixed".into()]).unwrap();
        assert_eq!(cfg.solver.precision, Precision::Mixed);
        assert!(Config::from_toml_str(
            "",
            &["solver.kind=cg".into(), "solver.precision=mixed".into()]
        )
        .is_err());
    }

    #[test]
    fn structured_keys_parse_and_cross_validate() {
        // Defaults: single block, auto inner kind, PR-5-grade tolerance.
        let cfg = Config::from_toml_str("", &[]).unwrap();
        assert_eq!(cfg.solver.blocks, 0);
        assert_eq!(cfg.solver.block_kind, BlockKind::Auto);
        assert_eq!(cfg.solver.hybrid_tol, 1e-10);
        // Full parse on a structured kind, flowing through to options.
        let cfg = Config::from_toml_str(
            "[solver]\nkind = \"hybrid\"\nblocks = 8\nblock_kind = \"rvb\"\n\
             hybrid_tol = 1e-9\n",
            &[],
        )
        .unwrap();
        assert_eq!(cfg.solver.kind, SolverKind::Hybrid);
        assert_eq!(cfg.solver.blocks, 8);
        assert_eq!(cfg.solver.block_kind, BlockKind::Rvb);
        assert_eq!(cfg.solver.hybrid_tol, 1e-9);
        let opts = cfg.solver.options();
        assert_eq!(opts.blocks, 8);
        assert_eq!(opts.block_kind, BlockKind::Rvb);
        assert_eq!(opts.hybrid_tol, 1e-9);
        // kpsvd takes blocks but has no inner session kind.
        let cfg =
            Config::from_toml_str("[solver]\nkind = \"kpsvd\"\nblocks = 4\n", &[]).unwrap();
        assert_eq!(cfg.solver.kind, SolverKind::KpSvd);
        let err = Config::from_toml_str(
            "[solver]\nkind = \"kpsvd\"\nblock_kind = \"chol\"\n",
            &[],
        )
        .unwrap_err();
        assert!(err.contains("solver.block_kind"), "{err}");
        // Block options on a dense kind are a config mistake, not inert.
        let err = Config::from_toml_str("[solver]\nblocks = 4\n", &[]).unwrap_err();
        assert!(err.contains("solver.blocks"), "{err}");
        let err =
            Config::from_toml_str("[solver]\nkind = \"eigh\"\nblock_kind = \"chol\"\n", &[])
                .unwrap_err();
        assert!(err.contains("solver.block_kind"), "{err}");
        // Bad values go through the shared option validators.
        assert!(Config::from_toml_str("[solver]\nblock_kind = \"kfac\"\n", &[]).is_err());
        assert!(Config::from_toml_str("[solver]\nhybrid_tol = 0.0\n", &[]).is_err());
        // mixed precision composes through blockdiag/hybrid inner
        // sessions but stays rejected for the eigendecomposition kind.
        for kind in ["blockdiag", "hybrid"] {
            let cfg = Config::from_toml_str(
                &format!("[solver]\nkind = \"{kind}\"\nprecision = \"mixed\"\n"),
                &[],
            )
            .unwrap();
            assert_eq!(cfg.solver.precision, Precision::Mixed);
        }
        let err = Config::from_toml_str(
            "[solver]\nkind = \"kpsvd\"\nprecision = \"mixed\"\n",
            &[],
        )
        .unwrap_err();
        assert!(err.contains("kpsvd"), "{err}");
        // The --set override path goes through the same keys…
        let cfg = Config::from_toml_str(
            "",
            &[
                "solver.kind=blockdiag".into(),
                "solver.blocks=16".into(),
                "solver.block_kind=chol".into(),
            ],
        )
        .unwrap();
        assert_eq!(cfg.solver.blocks, 16);
        assert_eq!(cfg.solver.block_kind, BlockKind::Chol);
        // …and misspelled structured keys hard-error like any other.
        for bogus in ["solver.block", "solver.block_count", "solver.hybridtol"] {
            let err =
                Config::from_toml_str("", &[format!("{bogus}=1")]).unwrap_err();
            assert!(err.contains("unknown config key"), "{bogus}: {err}");
        }
    }

    #[test]
    fn bad_override_reports() {
        assert!(Config::from_toml_str("", &["no_equals".into()]).is_err());
    }

    #[test]
    fn serve_keys_parse_from_toml_and_set() {
        let cfg = Config::from_toml_str(
            "[serve]\ntenants = 4\nqueue_depth = 32\ntick_ms = 5\nbudget_gb = 2.5\n\
             transport = \"socket\"\n",
            &[],
        )
        .unwrap();
        assert_eq!(cfg.serve.tenants, 4);
        assert_eq!(cfg.serve.queue_depth, 32);
        assert_eq!(cfg.serve.tick_ms, 5);
        assert_eq!(cfg.serve.budget_gb, 2.5);
        assert_eq!(cfg.serve.transport, "socket");
        // The --set override path reaches the same keys.
        let cfg = Config::from_toml_str(
            "",
            &["serve.tenants=2".into(), "serve.transport=channels".into()],
        )
        .unwrap();
        assert_eq!(cfg.serve.tenants, 2);
        assert_eq!(cfg.serve.transport, "channels");
        // Defaults: 16 tenants, channels transport, A100 budget.
        let cfg = Config::from_toml_str("", &[]).unwrap();
        assert_eq!(cfg.serve, ServeConfig::default());
    }

    #[test]
    fn serve_keys_cross_validate() {
        // Unknown keys hard-error like every other section.
        let err = Config::from_toml_str("[serve]\nbogus = 1\n", &[]).unwrap_err();
        assert!(err.contains("unknown config key"), "{err}");
        // queue_depth must cover every tenant slot.
        let err = Config::from_toml_str("[serve]\ntenants = 8\nqueue_depth = 4\n", &[])
            .unwrap_err();
        assert!(err.contains("serve.queue_depth"), "{err}");
        // Transport names go through the one shared parser.
        let err =
            Config::from_toml_str("[serve]\ntransport = \"pigeon\"\n", &[]).unwrap_err();
        assert!(err.contains("serve.transport"), "{err}");
        assert!(Config::from_toml_str("[serve]\ntenants = 0\n", &[]).is_err());
        assert!(Config::from_toml_str("[serve]\nbudget_gb = -1.0\n", &[]).is_err());
        assert!(Config::from_toml_str("[serve]\ntick_ms = 999999\n", &[]).is_err());
    }

    #[test]
    fn fault_tolerance_keys_parse_and_validate() {
        let cfg = Config::from_toml_str(
            "[serve]\ndeadline_ms = 250\nmax_retries = 2\nsnapshot_every = 8\n\
             supervise = false\nrecord_dir = \"/tmp/records\"\n",
            &[],
        )
        .unwrap();
        assert_eq!(cfg.serve.deadline_ms, 250);
        assert_eq!(cfg.serve.max_retries, 2);
        assert_eq!(cfg.serve.snapshot_every, 8);
        assert!(!cfg.serve.supervise);
        assert_eq!(cfg.serve.record_dir, "/tmp/records");
        // The --set override path reaches the same keys.
        let cfg = Config::from_toml_str("", &["serve.deadline_ms=99".into()]).unwrap();
        assert_eq!(cfg.serve.deadline_ms, 99);
        // Ranges are enforced where the config is parsed.
        assert!(Config::from_toml_str("[serve]\ndeadline_ms = 0\n", &[]).is_err());
        assert!(Config::from_toml_str("[serve]\ndeadline_ms = 600001\n", &[]).is_err());
        assert!(Config::from_toml_str("[serve]\nsnapshot_every = 0\n", &[]).is_err());
    }

    #[test]
    fn chaos_keys_parse_and_validate() {
        let cfg = Config::from_toml_str(
            "[chaos]\nschedule = \"respawn-storm\"\nseed = 7\nrequests = 25\nkill_every = 5\n",
            &[],
        )
        .unwrap();
        assert_eq!(cfg.chaos.schedule, "respawn-storm");
        assert_eq!(cfg.chaos.seed, 7);
        assert_eq!(cfg.chaos.requests, 25);
        assert_eq!(cfg.chaos.kill_every, 5);
        // Defaults run every schedule.
        let cfg = Config::from_toml_str("", &[]).unwrap();
        assert_eq!(cfg.chaos, ChaosConfig::default());
        assert_eq!(cfg.chaos.schedule, "all");
        // Schedule names go through the one shared parser.
        let err = Config::from_toml_str("[chaos]\nschedule = \"segfault\"\n", &[]).unwrap_err();
        assert!(err.contains("chaos.schedule"), "{err}");
        assert!(Config::from_toml_str("[chaos]\nrequests = 0\n", &[]).is_err());
        assert!(Config::from_toml_str("[chaos]\nkill_every = 0\n", &[]).is_err());
    }
}
