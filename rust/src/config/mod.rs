//! Configuration system: a from-scratch TOML-subset parser, typed config
//! structs for every subsystem, validation, and `key=value` CLI overrides.
//!
//! The launcher reads a config file (see `configs/` in the repo root),
//! applies `--set section.key=value` overrides, validates, and hands the
//! typed [`Config`] to the coordinator.

pub mod schema;
pub mod toml;

pub use schema::{
    ChaosConfig, Config, CoordinatorConfig, ModelConfig, ServeConfig, SolverConfig, TrainConfig,
    VmcConfig,
};
pub use toml::{parse_toml, TomlError, TomlValue};
