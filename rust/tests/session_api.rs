//! Property tests for the PR-2 plan/factor/solve session API.
//!
//! Invariants checked:
//!  S1. `solve_many` ≡ looped single-RHS `solve` for every solver kind
//!      (structured right-hand sides for `rvb`, whose precondition is
//!      `v = Sᵀf`).
//!  S2. Re-damping a cached `Factorization` with a new λ matches a cold
//!      `factor` at that λ to ≤ 1e-12 — the session path performs exactly
//!      the arithmetic of the cold path.
//!  S3. A λ-resweep on a cached factorization performs **zero** GEMM
//!      calls on the Gram path, and factor-once + k solves forms the Gram
//!      exactly once — pinned by the thread-local kernel call counters.
//!  S4. The registry surfaces `rvb`'s precondition as `BadInput` and
//!      rejects unknown per-solver options as hard errors.
//!  S5. The distributed sharded session agrees with the serial session
//!      across right-hand sides and λ-resweeps.
//!  S7. (PR 3) A chol session built through the registry with
//!      `solver.threads = t` produces bit-identical results for every
//!      t — the full `begin → redamp → solve_many` pipeline (Gram,
//!      lookahead Cholesky, panel GEMMs, threaded TRSM) is
//!      deterministic, so `threads` is a pure throughput knob.
//!  S8. (PR 4) Steady-state `redamp + solve` on a warmed chol/rvb
//!      session performs **zero** pack-buffer allocations — the
//!      thread-local packing arenas are grown monotonically and reused
//!      — pinned by the arena-allocation counter; the new TRSM/Cholesky
//!      invocation counters account for exactly the expected kernel
//!      front-end calls.

use dngd::coordinator::ShardedCholSolver;
use dngd::data::rng::Rng;
use dngd::linalg::kernel::counters;
use dngd::linalg::Mat;
use dngd::solver::{
    make_solver, residual_norm, CholSolver, DampedSolver, SolveError, SolverKind, SolverOptions,
    SolverRegistry,
};

/// Right-hand-side block for `kind`: random rows in general, rows from
/// the rowspace of S for `rvb`.
fn rhs_block(kind: SolverKind, s: &Mat, k: usize, rng: &mut Rng) -> Mat {
    let (n, m) = s.shape();
    if kind == SolverKind::Rvb {
        let mut vs = Mat::zeros(k, m);
        for r in 0..k {
            let f: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            vs.row_mut(r).copy_from_slice(&s.t_matvec(&f));
        }
        vs
    } else {
        Mat::randn(k, m, rng)
    }
}

#[test]
fn s1_solve_many_matches_looped_solve_for_every_kind() {
    let mut rng = Rng::seed_from(7001);
    for &kind in SolverKind::all() {
        for &(n, m, k) in &[(6usize, 30usize, 1usize), (14, 60, 5), (17, 90, 9)] {
            let s = Mat::randn(n, m, &mut rng);
            let vs = rhs_block(kind, &s, k, &mut rng);
            let lambda = 0.05;
            let solver = make_solver(kind);
            let mut fact = solver.factor(&s, lambda).unwrap_or_else(|e| {
                panic!("{kind:?} factor failed at ({n},{m}): {e}")
            });
            let many = fact.solve_many(&vs).unwrap();
            assert_eq!(many.shape(), (k, m));
            for r in 0..k {
                let one = fact.solve(vs.row(r)).unwrap();
                let scale = one.iter().fold(1.0f64, |a, x| a.max(x.abs()));
                for j in 0..m {
                    assert!(
                        (many[(r, j)] - one[j]).abs() < 1e-9 * scale,
                        "{kind:?} ({n},{m}) rhs {r} col {j}: {} vs {}",
                        many[(r, j)],
                        one[j]
                    );
                }
            }
        }
    }
}

#[test]
fn s2_redamp_matches_cold_factor() {
    let mut rng = Rng::seed_from(7002);
    for &kind in SolverKind::all() {
        let (n, m) = (12usize, 48usize);
        let s = Mat::randn(n, m, &mut rng);
        let vs = rhs_block(kind, &s, 1, &mut rng);
        let v = vs.row(0);
        let (l1, l2) = (0.5, 0.003);
        let solver = make_solver(kind);
        // Warm: factor at λ1, then resweep to λ2 on the cached state.
        let mut warm = solver.factor(&s, l1).unwrap();
        warm.redamp(l2).unwrap();
        let x_warm = warm.solve(v).unwrap();
        // Cold: factor directly at λ2.
        let mut cold = solver.factor(&s, l2).unwrap();
        let x_cold = cold.solve(v).unwrap();
        let scale = x_cold.iter().fold(1.0f64, |a, x| a.max(x.abs()));
        for (a, b) in x_warm.iter().zip(&x_cold) {
            assert!(
                (a - b).abs() <= 1e-12 * scale,
                "{kind:?}: warm {a} vs cold {b}"
            );
        }
        // And the resweep really solves the λ2 system.
        let res = residual_norm(&s, &x_warm, v, l2);
        assert!(res < 1e-7 * scale.max(1.0), "{kind:?}: residual {res}");
    }
}

#[test]
fn s3_lambda_resweep_performs_zero_gram_gemms() {
    // Thread-local counters: this test's deltas cannot be polluted by
    // concurrently running tests (serial SYRK runs on the calling thread).
    let mut rng = Rng::seed_from(7003);
    let (n, m, k) = (48usize, 256usize, 8usize);
    let s = Mat::randn(n, m, &mut rng);
    let vs = Mat::randn(k, m, &mut rng);
    let solver = CholSolver::default();

    // Factor once + k RHS + a 3-λ resweep: exactly ONE Gram formation.
    let syrk0 = counters::syrk_calls();
    let mut fact = solver.factor(&s, 1e-2).unwrap();
    assert_eq!(counters::syrk_calls() - syrk0, 1, "factor must form the Gram exactly once");

    let syrk1 = counters::syrk_calls();
    let x = fact.solve_many(&vs).unwrap();
    for r in 0..k {
        fact.solve(vs.row(r)).unwrap();
    }
    assert_eq!(
        counters::syrk_calls() - syrk1,
        0,
        "per-RHS solves must not re-form the Gram"
    );

    // λ-resweep: zero GEMM calls of any flavour — n=48 < NB keeps the
    // refactor inside the unblocked Cholesky panel, so the whole resweep
    // is kernel-silent.
    let (syrk2, dgemm2) = (counters::syrk_calls(), counters::dgemm_calls());
    fact.redamp(1e-3).unwrap();
    fact.redamp(1e-4).unwrap();
    fact.redamp(1e-2).unwrap();
    assert_eq!(counters::syrk_calls() - syrk2, 0, "λ resweep must not re-form the Gram");
    assert_eq!(counters::dgemm_calls() - dgemm2, 0, "λ resweep at n<NB must be GEMM-free");

    // Still correct after the sweep (back at λ=1e-2).
    let res = residual_norm(&s, x.row(0), vs.row(0), 1e-2);
    let scale = s.fro_norm().powi(2) * dngd::linalg::mat::norm2(x.row(0))
        + dngd::linalg::mat::norm2(vs.row(0));
    assert!(res < 1e-9 * scale.max(1.0));
}

#[test]
fn s4_registry_surfaces_rvb_precondition_and_rejects_unknown_options() {
    let mut rng = Rng::seed_from(7004);
    let s = Mat::randn(5, 40, &mut rng);

    // rvb reachable by name through parse + registry…
    let kind = SolverKind::parse("rvb").expect("rvb must be parseable");
    let solver = SolverRegistry::default().build(kind);
    assert_eq!(solver.name(), "rvb");
    // …and its v = Sᵀf precondition surfaces as BadInput.
    let v_bad: Vec<f64> = (0..40).map(|_| rng.normal()).collect();
    match solver.solve(&s, &v_bad, 0.1) {
        Err(SolveError::BadInput(msg)) => assert!(msg.contains("rowspace"), "{msg}"),
        other => panic!("expected BadInput(rowspace), got {other:?}"),
    }
    // Structured input goes through and matches chol.
    let f: Vec<f64> = (0..5).map(|_| rng.normal()).collect();
    let v = s.t_matvec(&f);
    let x = solver.solve(&s, &v, 0.1).unwrap();
    let x_ref = CholSolver::default().solve(&s, &v, 0.1).unwrap();
    for (a, b) in x.iter().zip(&x_ref) {
        assert!((a - b).abs() < 1e-7);
    }

    // Per-solver options flow through the registry; unknown keys are
    // hard errors (no-silent-ignore), including from --set strings.
    let reg = SolverRegistry::from_overrides(&[
        "solver.cg_tol=1e-6".into(),
        "solver.cg_max_iters=77".into(),
    ])
    .unwrap();
    assert_eq!(reg.opts.cg_tol, 1e-6);
    assert_eq!(reg.opts.cg_max_iters, 77);
    assert!(SolverRegistry::from_overrides(&["solver.tolerance=1e-6".into()]).is_err());
    assert!(SolverRegistry::from_overrides(&["train.steps=5".into()]).is_err());
    let mut opts = SolverOptions::default();
    assert!(opts.apply("nope", "1").is_err());
    assert!(opts.apply("threads", "3").is_ok());
}

#[test]
fn s5_sharded_session_matches_serial_across_rhs_and_resweeps() {
    let mut rng = Rng::seed_from(7005);
    let (n, m, k) = (10usize, 64usize, 4usize);
    let s = Mat::randn(n, m, &mut rng);
    let vs = Mat::randn(k, m, &mut rng);
    let sharded = ShardedCholSolver::new(3, 2);
    let serial = CholSolver::default();

    let mut fd = sharded.factor(&s, 0.1).unwrap();
    let mut fs = serial.factor(&s, 0.1).unwrap();
    for &lambda in &[0.1, 0.004] {
        fd.redamp(lambda).unwrap();
        fs.redamp(lambda).unwrap();
        let xd = fd.solve_many(&vs).unwrap();
        let xs = fs.solve_many(&vs).unwrap();
        for r in 0..k {
            for j in 0..m {
                assert!(
                    (xd[(r, j)] - xs[(r, j)]).abs() < 1e-9,
                    "λ={lambda} rhs {r} col {j}"
                );
            }
        }
    }
}

#[test]
fn s7_registry_threaded_session_bit_identical_round_trip() {
    let mut rng = Rng::seed_from(7007);
    let (n, m, k) = (160usize, 512usize, 6usize);
    let s = Mat::randn(n, m, &mut rng);
    let vs = Mat::randn(k, m, &mut rng);
    let run = |threads: usize| -> Mat {
        let mut opts = SolverOptions::default();
        opts.apply("threads", &threads.to_string()).unwrap();
        let reg = SolverRegistry::new(opts);
        let plan = reg.plan(SolverKind::Chol, n, m);
        let fact = plan.begin(&s);
        let mut fact = fact.unwrap();
        fact.redamp(5e-3).unwrap();
        fact.solve_many(&vs).unwrap()
    };
    let reference = run(1);
    for threads in [2usize, 4, 8] {
        let x = run(threads);
        assert_eq!(
            x.as_slice(),
            reference.as_slice(),
            "registry chol session at solver.threads={threads} is not bit-identical to serial"
        );
    }
    // And it actually solves the damped system.
    let res = residual_norm(&s, reference.row(0), vs.row(0), 5e-3);
    let scale = s.fro_norm().powi(2) * dngd::linalg::mat::norm2(reference.row(0))
        + dngd::linalg::mat::norm2(vs.row(0));
    assert!(res < 1e-9 * scale.max(1.0), "residual {res}");
}

#[test]
fn s8_steady_state_redamp_solve_is_pack_allocation_free() {
    // Serial sessions (threads = 1): every kernel — Gram SYRK, blocked
    // Cholesky, TRSM, panel GEMMs — runs on this thread, so the
    // thread-local arena/invocation counters capture all of it and
    // concurrently running tests cannot pollute the deltas.
    let mut rng = Rng::seed_from(7008);
    // n > NB = 64 so the blocked Cholesky, its panel TRSM and the
    // trailing-downdate dgemm all engage (a λ-resweep is NOT
    // kernel-silent at this size, unlike s3's n = 48).
    let (n, m, k) = (160usize, 384usize, 6usize);
    for &kind in &[SolverKind::Chol, SolverKind::Rvb] {
        let s = Mat::randn(n, m, &mut rng);
        let vs = rhs_block(kind, &s, k, &mut rng);
        let solver = make_solver(kind);

        // Warm-up: factor + solve_many at two λs grows every arena slot
        // (pack A/B, Cholesky strip + gathers, TRSM panels) to its
        // steady-state size for these shapes.
        let mut fact = solver.factor(&s, 1e-2).unwrap();
        fact.solve_many(&vs).unwrap();
        fact.redamp(1e-3).unwrap();
        fact.solve_many(&vs).unwrap();

        // Steady state: one more redamp + blocked solve must perform
        // ZERO pack-buffer allocations.
        let arena0 = counters::arena_allocs();
        let chol0 = counters::cholesky_calls();
        let trsm0 = counters::trsm_calls();
        fact.redamp(1e-2).unwrap();
        let x = fact.solve_many(&vs).unwrap();
        assert_eq!(
            counters::arena_allocs() - arena0,
            0,
            "{kind:?}: steady-state redamp+solve_many must not grow the packing arenas"
        );
        // Invocation accounting: one refactor per redamp; the chol
        // session's solve_many runs the blocked TRSM pair, while rvb's
        // per-RHS identity path uses vector substitutions (no multi-RHS
        // TRSM front-end).
        assert_eq!(counters::cholesky_calls() - chol0, 1, "{kind:?}: one Cholesky per redamp");
        let expected_trsm = if kind == SolverKind::Chol { 2 } else { 0 };
        assert_eq!(counters::trsm_calls() - trsm0, expected_trsm, "{kind:?}: TRSM front-ends");

        // And the steady-state result is still correct.
        let res = residual_norm(&s, x.row(0), vs.row(0), 1e-2);
        let scale = s.fro_norm().powi(2) * dngd::linalg::mat::norm2(x.row(0))
            + dngd::linalg::mat::norm2(vs.row(0));
        assert!(res < 1e-9 * scale.max(1.0), "{kind:?}: residual {res}");
    }
}

#[test]
fn s9_window_rotation_performs_zero_full_gram_syrks() {
    // PR 5: a sliding-window rotation on the chol/rvb owned-window
    // sessions patches the cached Gram with panel GEMMs and rotates
    // the factor in O(kn²) — the SYRK and Cholesky front-ends must
    // both stay silent (the Gram is never re-formed, the factor never
    // re-factored), and the same-λ redamp after a rotation must be a
    // no-op rather than an O(n³) refactor.
    let mut rng = Rng::seed_from(7009);
    let (n, m, k) = (32usize, 128usize, 4usize);
    for &kind in &[SolverKind::Chol, SolverKind::Rvb] {
        let s = Mat::randn(n, m, &mut rng);
        let solver = make_solver(kind);
        let mut fact = solver
            .begin_window(s.clone())
            .expect("chol/rvb have owned-window sessions");
        fact.redamp(1e-2).unwrap();
        // Warm every lazy cache (rvb's recovery factor) pre-rotation.
        let warm_v = rhs_block(kind, &s, 1, &mut rng);
        fact.solve(warm_v.row(0)).unwrap();

        let added = Mat::randn(k, m, &mut rng);
        let removed: Vec<usize> = (0..k).collect();
        let syrk0 = counters::syrk_calls();
        let chol0 = counters::cholesky_calls();
        fact.update_rows(&removed, &added).unwrap();
        fact.redamp(1e-2).unwrap();
        assert_eq!(
            counters::syrk_calls() - syrk0,
            0,
            "{kind:?}: a window rotation must never re-form the Gram (zero full-Gram SYRKs)"
        );
        assert_eq!(
            counters::cholesky_calls() - chol0,
            0,
            "{kind:?}: rotation + same-λ redamp must rotate the factor, not refactor it"
        );

        // The rotated session still solves its rotated window.
        let mut rotated = Mat::zeros(n, m);
        for i in 0..n - k {
            rotated.row_mut(i).copy_from_slice(s.row(i + k));
        }
        for j in 0..k {
            rotated.row_mut(n - k + j).copy_from_slice(added.row(j));
        }
        let vs = rhs_block(kind, &rotated, 1, &mut rng);
        let x = fact.solve(vs.row(0)).unwrap();
        let res = residual_norm(&rotated, &x, vs.row(0), 1e-2);
        let fro = rotated.fro_norm();
        let scale = fro * fro * dngd::linalg::mat::norm2(&x)
            + dngd::linalg::mat::norm2(vs.row(0));
        assert!(res < 1e-8 * scale.max(1.0), "{kind:?}: rotated residual {res}");
    }
}

#[test]
fn s6_plan_shape_gate_and_factor_reuse_across_steps() {
    let mut rng = Rng::seed_from(7006);
    let (n, m) = (8usize, 32usize);
    let plan = SolverRegistry::default().plan(SolverKind::Chol, n, m);
    assert_eq!(plan.shape(), (n, m));
    // A training loop: one factor per step, several RHS per factor.
    for _ in 0..3 {
        let s = Mat::randn(n, m, &mut rng);
        let mut fact = plan.factor(&s, 0.05).unwrap();
        for _ in 0..2 {
            let v: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
            let x = fact.solve(&v).unwrap();
            assert!(residual_norm(&s, &x, &v, 0.05) < 1e-8);
        }
    }
    // Wrong shape is a typed error, not a kernel assert.
    let wrong = Mat::randn(n + 1, m, &mut rng);
    assert!(matches!(plan.factor(&wrong, 0.05), Err(SolveError::BadInput(_))));
}
