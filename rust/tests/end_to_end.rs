//! End-to-end smoke of the full system at test scale: NGD training
//! descends on real (synthetic-corpus) data through the complete
//! coordinator path, SR converges toward the exact ground state, and
//! checkpoint/resume works through the trainer.

use dngd::config::Config;
use dngd::coordinator::trainer::{OptimizerChoice, TRAIN_LOG_COLUMNS};
use dngd::coordinator::Trainer;
use dngd::data::rng::Rng;
use dngd::metrics::MetricsLog;
use dngd::ngd::DampingSchedule;
use dngd::vmc::{ground_state_energy, IsingChain, MetropolisSampler, Rbm, SrDriver, SrVariant};

fn small_train_cfg(extra: &[&str]) -> Config {
    let mut overrides: Vec<String> = vec![
        "model.dim=12".into(),
        "model.heads=2".into(),
        "model.layers=2".into(),
        "model.context=12".into(),
        "model.mlp_hidden=32".into(),
        "train.steps=25".into(),
        "train.batch_size=32".into(),
        "train.corpus_len=20000".into(),
        "train.learning_rate=0.5".into(),
        "train.momentum=0.5".into(),
        "solver.lambda=0.2".into(),
        "solver.adaptive=true".into(),
        "coordinator.workers=4".into(),
        "coordinator.use_artifacts=false".into(),
    ];
    overrides.extend(extra.iter().map(|s| s.to_string()));
    Config::load(None, &overrides).unwrap()
}

#[test]
fn ngd_training_beats_uniform_by_a_wide_margin() {
    let cfg = small_train_cfg(&[]);
    let mut trainer = Trainer::new(&cfg, OptimizerChoice::Ngd).unwrap();
    let uniform = (trainer.tokenizer.vocab_size() as f64).ln();
    let mut log = MetricsLog::new(TRAIN_LOG_COLUMNS);
    let report = trainer.run(&mut log).unwrap();
    assert!(
        report.final_loss < 0.8 * uniform,
        "NGD failed to learn: {} vs uniform {uniform}",
        report.final_loss
    );
    // The loss curve must be broadly decreasing.
    let losses = log.column("loss").unwrap();
    let q = losses.len() / 4;
    let head: f64 = losses[..q].iter().sum::<f64>() / q as f64;
    let tail: f64 = losses[losses.len() - q..].iter().sum::<f64>() / q as f64;
    assert!(tail < head, "loss not decreasing: head {head} tail {tail}");
}

#[test]
fn ngd_descends_faster_per_step_than_sgd_early() {
    // The optimizer-quality motivation behind NGD (§1): per-step progress.
    let cfg = small_train_cfg(&[]);
    let mut ngd = Trainer::new(&cfg, OptimizerChoice::Ngd).unwrap();
    let mut ngd_log = MetricsLog::new(TRAIN_LOG_COLUMNS);
    ngd.run(&mut ngd_log).unwrap();

    let sgd_cfg = small_train_cfg(&["train.learning_rate=0.3", "train.momentum=0.9"]);
    let mut sgd = Trainer::new(&sgd_cfg, OptimizerChoice::Sgd).unwrap();
    let mut sgd_log = MetricsLog::new(TRAIN_LOG_COLUMNS);
    sgd.run(&mut sgd_log).unwrap();

    // Compare the mean over steps 8–12 (single-step comparisons are noisy).
    let ngd_losses = ngd_log.column("loss").unwrap();
    let sgd_losses = sgd_log.column("loss").unwrap();
    let ngd_mid: f64 = ngd_losses[8..13].iter().sum::<f64>() / 5.0;
    let sgd_mid: f64 = sgd_losses[8..13].iter().sum::<f64>() / 5.0;
    assert!(
        ngd_mid < sgd_mid,
        "NGD not faster per-step around step 10: ngd {ngd_mid} vs sgd {sgd_mid}"
    );
}

#[test]
fn sr_energy_approaches_exact_ground_state() {
    let sites = 4;
    let chain = IsingChain::new(sites, 1.0, 1.0);
    let exact = ground_state_energy(&chain, 40_000, 1e-12);
    let mut rng = Rng::seed_from(700);
    let mut rbm = Rbm::init(sites, 8, 0.05, &mut rng);
    let mut sampler = MetropolisSampler::new(&rbm, &mut rng);
    for _ in 0..50 {
        sampler.sweep(&rbm, &mut rng);
    }
    let mut driver = SrDriver::new(chain, 200, 0.08, 0.05).with_variant(SrVariant::FullComplex);
    driver.damping = DampingSchedule::ExponentialDecay { initial: 0.05, decay: 0.97, min: 1e-4 };
    let mut last = f64::INFINITY;
    for _ in 0..60 {
        last = driver.step(&mut rbm, &mut sampler, &mut rng).unwrap().energy;
    }
    let rel = (last - exact).abs() / exact.abs();
    assert!(rel < 0.05, "SR energy {last} vs exact {exact} (rel {rel})");
}

#[test]
fn checkpoint_resume_continues_descent() {
    let dir = std::env::temp_dir().join("dngd_e2e_resume");
    std::fs::remove_dir_all(&dir).ok();
    let dir_s = dir.to_string_lossy().to_string();
    let ckpt_override = format!("train.checkpoint_dir=\"{dir_s}\"");
    let cfg = small_train_cfg(&[&ckpt_override, "train.checkpoint_every=25", "train.steps=25"]);
    let mut first = Trainer::new(&cfg, OptimizerChoice::Ngd).unwrap();
    let mut log = MetricsLog::new(TRAIN_LOG_COLUMNS);
    let report1 = first.run(&mut log).unwrap();

    // Fresh trainer, resume from the checkpoint and continue to step 50:
    // the first-step loss must be near the previous run's final loss,
    // not the init loss (resume continues the step cursor, so the
    // second run needs a larger train.steps to execute anything).
    let cfg2 = small_train_cfg(&[&ckpt_override, "train.checkpoint_every=25", "train.steps=50"]);
    let mut second = Trainer::new(&cfg2, OptimizerChoice::Ngd).unwrap();
    let step = second.load_checkpoint(&dir.join("step_25.ckpt")).unwrap();
    assert_eq!(step, 25);
    let mut log2 = MetricsLog::new(TRAIN_LOG_COLUMNS);
    let report2 = second.run(&mut log2).unwrap();
    assert_eq!(report2.steps, 50);
    assert_eq!(log2.len(), 25, "resumed run executes only the remaining steps");
    assert!(
        report2.initial_loss < (report1.initial_loss + report1.final_loss) / 2.0,
        "resume did not pick up trained params: {} vs init {}",
        report2.initial_loss,
        report1.initial_loss
    );
    std::fs::remove_dir_all(&dir).ok();
}
