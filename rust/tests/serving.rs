//! PR-7 serving-layer integration suite.
//!
//! The transport-equivalence contract: the in-process channel pool and
//! the length-prefixed Unix-socket transport must produce **bit-identical**
//! answers over the whole PR-2 session API — cold factor, λ-resweep,
//! multi-RHS `solve_many`, and the PR-5 streaming `update_rows` rotation
//! — at worker-kernel thread counts 1 and 8. Both transports route every
//! request through the same `execute_request` compute path, so any bit
//! divergence means the framing layer corrupted a payload.
//!
//! Fault injection: killing a worker must surface as the typed *fatal*
//! [`SolveError::Backend`] (never a hang, never a retryable), on both
//! transports.
//!
//! PR-8 chaos soak: every scripted fault schedule (kill-during-factor,
//! stall-during-panel, corrupt-frame, respawn-storm) must end with
//! answers ≤ 1e-9 from a fault-free reference, zero leaked sessions or
//! budget bytes, and the expected supervisor counters — over both
//! transports at kernel thread counts 1 and 8.

use dngd::coordinator::ShardedCholSolver;
use dngd::data::rng::Rng;
use dngd::linalg::{KernelConfig, Mat};
use dngd::solver::{CholSolver, DampedSolver, Factorization, SolveError};
use std::sync::Arc;

#[cfg(unix)]
use dngd::serve::SocketTransport;
#[cfg(unix)]
use dngd::serve::{ServeOptions, Server, TransportKind};

/// Fixed workload inputs, regenerated identically for every transport
/// and for the serial reference.
fn workload_data() -> (Mat, Vec<f64>, Mat, Mat) {
    let mut rng = Rng::seed_from(700);
    let s = Mat::randn(10, 64, &mut rng);
    let v: Vec<f64> = (0..64).map(|_| rng.normal()).collect();
    let vs = Mat::randn(4, 64, &mut rng);
    let added = Mat::randn(2, 64, &mut rng);
    (s, v, vs, added)
}

/// The rotated window `update_rows(&[0, 2], added)` produces: kept rows
/// in order, then the added rows appended at the bottom.
fn rotate_reference(s: &Mat, removed: &[usize], added: &Mat) -> Mat {
    let mut data = Vec::with_capacity((s.rows() - removed.len() + added.rows()) * s.cols());
    for i in 0..s.rows() {
        if !removed.contains(&i) {
            data.extend_from_slice(s.row(i));
        }
    }
    for r in 0..added.rows() {
        data.extend_from_slice(added.row(r));
    }
    Mat::from_vec(s.rows() - removed.len() + added.rows(), s.cols(), data)
}

/// Run the full PR-2 + PR-5 session API through one sharded solver:
/// cold factor → solve, λ-resweep → solve, 4-RHS panel, then an owned
/// window session with a streaming rotation. Returns every answer in a
/// fixed order for cross-transport comparison.
fn run_session_workload(solver: &Arc<ShardedCholSolver>) -> Vec<Vec<f64>> {
    let (s, v, vs, added) = workload_data();
    let mut answers = Vec::new();
    {
        let mut fact = solver.factor(&s, 0.05).unwrap();
        answers.push(fact.solve(&v).unwrap());
        fact.redamp(0.005).unwrap();
        answers.push(fact.solve(&v).unwrap());
        let xs = fact.solve_many(&vs).unwrap();
        for r in 0..xs.rows() {
            answers.push(xs.row(r).to_vec());
        }
    }
    let mut sess = ShardedCholSolver::window_session(solver, s);
    sess.redamp(0.05).unwrap();
    answers.push(sess.solve(&v).unwrap());
    sess.update_rows(&[0, 2], &added).unwrap();
    answers.push(sess.solve(&v).unwrap());
    answers
}

/// Serial `chol` answers for the same workload, for the 1e-9 accuracy
/// gate (bitwise equality is only asserted *between* transports — the
/// distributed tree reduction reorders shard sums vs the serial Gram).
fn serial_reference() -> Vec<Vec<f64>> {
    let (s, v, vs, added) = workload_data();
    let serial = CholSolver::default();
    let mut refs = Vec::new();
    refs.push(serial.solve(&s, &v, 0.05).unwrap());
    refs.push(serial.solve(&s, &v, 0.005).unwrap());
    for r in 0..vs.rows() {
        refs.push(serial.solve(&s, vs.row(r), 0.005).unwrap());
    }
    refs.push(serial.solve(&s, &v, 0.05).unwrap());
    let rotated = rotate_reference(&s, &[0, 2], &added);
    refs.push(serial.solve(&rotated, &v, 0.05).unwrap());
    refs
}

fn assert_close_to_serial(answers: &[Vec<f64>], label: &str) {
    let refs = serial_reference();
    assert_eq!(answers.len(), refs.len());
    for (i, (x, x_ref)) in answers.iter().zip(&refs).enumerate() {
        let scale = dngd::linalg::mat::norm2(x_ref).max(1.0);
        for (a, b) in x.iter().zip(x_ref) {
            assert!(
                (a - b).abs() < 1e-9 * scale,
                "{label}: answer {i} diverged from serial: {a} vs {b}"
            );
        }
    }
}

#[cfg(unix)]
#[test]
fn transports_bit_identical_over_session_api() {
    for &threads in &[1usize, 8] {
        let kernel = KernelConfig::with_threads(threads);
        let chan = Arc::new(ShardedCholSolver::with_kernel(3, 4, kernel));
        let sock = Arc::new(ShardedCholSolver::with_transport(
            Box::new(SocketTransport::spawn(3, kernel).expect("socket transport")),
            kernel,
        ));
        let a = run_session_workload(&chan);
        let b = run_session_workload(&sock);
        assert_eq!(a.len(), b.len());
        for (i, (xa, xb)) in a.iter().zip(&b).enumerate() {
            for (p, q) in xa.iter().zip(xb) {
                assert_eq!(
                    p.to_bits(),
                    q.to_bits(),
                    "threads={threads} answer {i}: channels {p} vs socket {q}"
                );
            }
        }
        assert_close_to_serial(&a, &format!("channels threads={threads}"));
        assert_close_to_serial(&b, &format!("socket threads={threads}"));
    }
}

#[test]
fn channel_transport_killed_worker_is_fatal_typed_error() {
    let mut rng = Rng::seed_from(701);
    let solver = ShardedCholSolver::new(2, 4);
    let s = Mat::randn(8, 32, &mut rng);
    let v: Vec<f64> = (0..32).map(|_| rng.normal()).collect();
    solver.kill_worker(0);
    match solver.solve_distributed(&s, &v, 0.1) {
        Err(SolveError::Backend { retryable, .. }) => {
            assert!(!retryable, "a dead worker is not a retry-later condition")
        }
        other => panic!("expected fatal Backend error, got {other:?}"),
    }
}

#[cfg(unix)]
#[test]
fn socket_transport_killed_worker_is_fatal_typed_error() {
    let mut rng = Rng::seed_from(702);
    let kernel = KernelConfig::serial();
    let solver = ShardedCholSolver::with_transport(
        Box::new(SocketTransport::spawn(2, kernel).expect("socket transport")),
        kernel,
    );
    let s = Mat::randn(8, 32, &mut rng);
    let v: Vec<f64> = (0..32).map(|_| rng.normal()).collect();
    solver.kill_worker(0);
    match solver.solve_distributed(&s, &v, 0.1) {
        Err(SolveError::Backend { retryable, .. }) => {
            assert!(!retryable, "a dead worker is not a retry-later condition")
        }
        other => panic!("expected fatal Backend error, got {other:?}"),
    }
}

#[cfg(unix)]
#[test]
fn server_round_trip_over_socket_transport() {
    let mut rng = Rng::seed_from(703);
    let s = Mat::randn(8, 40, &mut rng);
    let v: Vec<f64> = (0..40).map(|_| rng.normal()).collect();
    let x_ref = CholSolver::default().solve(&s, &v, 0.1).unwrap();

    let opts = ServeOptions {
        transport: TransportKind::Socket,
        workers: 2,
        tick_ms: 1,
        ..ServeOptions::default()
    };
    let server = Server::start(opts).expect("server start");
    assert_eq!(server.transport_name(), "socket");
    let client = server.client().unwrap();
    let sid = client.open_session(s, 0.1).unwrap();
    let x = client.solve(sid, 0.1, &v).unwrap();
    let scale = dngd::linalg::mat::norm2(&x_ref).max(1.0);
    for (a, b) in x.iter().zip(&x_ref) {
        assert!((a - b).abs() < 1e-9 * scale);
    }
    client.close_session(sid).unwrap();
    drop(client);
    let stats = server.shutdown();
    assert_eq!(stats.completed, 1);
}

/// PR-8 chaos soak: a seeded fault schedule matrix. Channels-only on
/// non-unix targets; on unix both transports run. Each cell is a full
/// `run_schedule` pass — correctness gate, leak checks, and the
/// schedule's counter assertions all fold into `report.passed`.
#[test]
fn chaos_soak_all_schedules_all_transports() {
    use dngd::serve::{chaos, ChaosOptions, FaultSchedule, TransportKind};

    let transports: &[TransportKind] = if cfg!(unix) {
        &[TransportKind::Channels, TransportKind::Socket]
    } else {
        &[TransportKind::Channels]
    };
    for &transport in transports {
        for &threads in &[1usize, 8] {
            let opts = ChaosOptions {
                transport,
                threads,
                requests: 20,
                kill_every: 6,
                ..ChaosOptions::default()
            };
            for schedule in FaultSchedule::all() {
                let report = chaos::run_schedule(schedule, &opts)
                    .unwrap_or_else(|e| panic!("{schedule} [{transport} t={threads}]: {e}"));
                assert!(
                    report.passed,
                    "{} [{} t={threads}]: {}",
                    report.schedule, report.transport, report.detail
                );
            }
        }
    }
}

/// A killed worker mid-stream must be healed by exactly one respawn and
/// one session re-materialization, with the recovery path visible in
/// the stats — the observability half of the PR-8 contract.
#[test]
fn recovery_path_is_observable_in_serve_stats() {
    use dngd::serve::{ServeOptions, Server};

    let mut rng = Rng::seed_from(704);
    let s = Mat::randn(8, 40, &mut rng);
    let v: Vec<f64> = (0..40).map(|_| rng.normal()).collect();
    let x_ref = CholSolver::default().solve(&s, &v, 0.1).unwrap();

    let server = Server::start(ServeOptions { workers: 2, tick_ms: 1, ..ServeOptions::default() })
        .expect("server start");
    let client = server.client().unwrap();
    let sid = client.open_session(s, 0.1).unwrap();
    client.solve(sid, 0.1, &v).unwrap();
    server.inject_kill(0);
    let x = client.solve(sid, 0.1, &v).unwrap();
    let scale = dngd::linalg::mat::norm2(&x_ref).max(1.0);
    for (a, b) in x.iter().zip(&x_ref) {
        assert!((a - b).abs() < 1e-9 * scale, "post-recovery answer diverged: {a} vs {b}");
    }
    client.close_session(sid).unwrap();
    drop(client);
    let stats = server.shutdown();
    assert_eq!(stats.completed, 2);
    assert_eq!(stats.worker_respawns, 1, "one kill → one respawn");
    assert_eq!(
        stats.session_replays + stats.session_refactors,
        1,
        "one kill → one distributed re-materialization"
    );
    assert_eq!(stats.local_fallbacks, 0, "routine heals must not hit the leader-local fallback");
}
