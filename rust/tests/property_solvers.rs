//! Randomized property tests over the whole solver surface (the in-tree
//! proptest-style harness: deterministic seeds, wide random sweeps,
//! shrink-free but fully reproducible — every failure prints its case).
//!
//! Invariants checked:
//!  P1. Every solver satisfies the normal equations (backward error).
//!  P2. All solvers agree pairwise on the same problem.
//!  P3. Solutions are linear in v: solve(αv₁ + βv₂) = α·x₁ + β·x₂.
//!  P4. Monotone damping: ‖x(λ)‖ is non-increasing in λ.
//!  P5. λ → ∞ limit: x → v/λ (damping dominates).
//!  P6. RVB equivalence on structured v, rejection on unstructured v.
//!  P7. Complex SR reduces to real on real inputs; real-part variant
//!      matches the stacked-real construction by definition and the
//!      dense oracle by value.
//!  P8. Sharded distributed solve == serial solve for random topologies.
//!  P9. (PR 5) A streaming window rotation (k rows deleted + appended)
//!      leaves a factor that matches a from-scratch `gram_factor` of
//!      the rotated window to 1e-9 — at every thread count and every
//!      supported ISA tier.
//!  P10. (PR 5) A bordered-append pivot below the relative floor
//!      triggers the downdate-breakdown → full-refactor fallback
//!      (observable on the Cholesky front-end counter) and the result
//!      still matches the cold factor.

use dngd::coordinator::ShardedCholSolver;
use dngd::data::rng::Rng;
use dngd::linalg::complex::{c64, CMat};
use dngd::linalg::{KernelConfig, Mat};
use dngd::solver::chol::CholFactor;
use dngd::solver::{
    make_solver, residual_norm, solve_sr_complex, CholSolver, DampedSolver, Factorization,
    RvbSolver, SolverKind,
};

fn random_problem(rng: &mut Rng) -> (Mat, Vec<f64>, f64) {
    let n = 1 + rng.below(20);
    let m = n + rng.below(120);
    let lambda = 10f64.powf(rng.uniform() * 4.0 - 3.0); // 1e-3 … 1e1
    let s = Mat::randn(n, m, rng);
    let v: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
    (s, v, lambda)
}

#[test]
fn p1_p2_backward_error_and_pairwise_agreement() {
    let mut rng = Rng::seed_from(9001);
    for case in 0..60 {
        let (s, v, lambda) = random_problem(&mut rng);
        let mut solutions: Vec<(&'static str, Vec<f64>)> = Vec::new();
        for &kind in &[SolverKind::Chol, SolverKind::Eigh, SolverKind::Svda, SolverKind::Cg] {
            let x = make_solver(kind)
                .solve(&s, &v, lambda)
                .unwrap_or_else(|e| panic!("case {case} {kind:?}: {e}"));
            let fro = s.fro_norm();
            let xnorm = x.iter().map(|a| a * a).sum::<f64>().sqrt();
            let vnorm = v.iter().map(|a| a * a).sum::<f64>().sqrt();
            let r = residual_norm(&s, &x, &v, lambda);
            let scale = (fro * fro + lambda) * xnorm + vnorm;
            assert!(
                r < 1e-8 * scale.max(1.0),
                "case {case} {kind:?}: residual {r:.3e} scale {scale:.3e} (n={}, m={}, λ={lambda:.3e})",
                s.rows(),
                s.cols()
            );
            solutions.push((kind.as_str(), x));
        }
        let (ref_name, ref_x) = &solutions[0];
        let ref_norm = ref_x.iter().map(|a| a * a).sum::<f64>().sqrt().max(1e-300);
        for (name, x) in &solutions[1..] {
            let diff = x
                .iter()
                .zip(ref_x.iter())
                .map(|(a, b)| (a - b) * (a - b))
                .sum::<f64>()
                .sqrt();
            assert!(
                diff < 1e-6 * ref_norm,
                "case {case}: {name} vs {ref_name} differ by {diff:.3e} (rel)"
            );
        }
    }
}

#[test]
fn p3_linearity_in_v() {
    let mut rng = Rng::seed_from(9002);
    let solver = CholSolver::default();
    for _ in 0..25 {
        let (s, v1, lambda) = random_problem(&mut rng);
        let m = s.cols();
        let v2: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
        let (alpha, beta) = (rng.normal(), rng.normal());
        let x1 = solver.solve(&s, &v1, lambda).unwrap();
        let x2 = solver.solve(&s, &v2, lambda).unwrap();
        let v12: Vec<f64> = v1.iter().zip(&v2).map(|(a, b)| alpha * a + beta * b).collect();
        let x12 = solver.solve(&s, &v12, lambda).unwrap();
        let scale = x12.iter().map(|a| a.abs()).fold(0.0f64, f64::max).max(1.0);
        for j in 0..m {
            let lin = alpha * x1[j] + beta * x2[j];
            assert!((x12[j] - lin).abs() < 1e-8 * scale);
        }
    }
}

#[test]
fn p4_p5_damping_monotonicity_and_limit() {
    let mut rng = Rng::seed_from(9003);
    let solver = CholSolver::default();
    for _ in 0..20 {
        let (s, v, _) = random_problem(&mut rng);
        let mut prev_norm = f64::INFINITY;
        for &lambda in &[1e-3, 1e-2, 1e-1, 1.0, 10.0, 100.0] {
            let x = solver.solve(&s, &v, lambda).unwrap();
            let norm = x.iter().map(|a| a * a).sum::<f64>().sqrt();
            assert!(
                norm <= prev_norm * (1.0 + 1e-9),
                "‖x‖ must be non-increasing in λ: {norm} after {prev_norm} at λ={lambda}"
            );
            prev_norm = norm;
        }
        // λ → ∞: x ≈ v/λ.
        let lambda = 1e9;
        let x = solver.solve(&s, &v, lambda).unwrap();
        for (xj, vj) in x.iter().zip(&v) {
            assert!((xj - vj / lambda).abs() < 1e-12 * vj.abs().max(1.0));
        }
    }
}

#[test]
fn p6_rvb_structured_vs_unstructured() {
    let mut rng = Rng::seed_from(9004);
    for _ in 0..20 {
        let n = 2 + rng.below(10);
        let m = n + 5 + rng.below(60);
        let lambda = 0.05;
        let s = Mat::randn(n, m, &mut rng);
        let f: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let v = s.t_matvec(&f);
        let x_rvb = RvbSolver::default().solve_ls(&s, &f, lambda).unwrap();
        let x_chol = CholSolver::default().solve(&s, &v, lambda).unwrap();
        let scale = x_chol.iter().map(|a| a.abs()).fold(0.0f64, f64::max).max(1.0);
        for (a, b) in x_rvb.iter().zip(&x_chol) {
            assert!((a - b).abs() < 1e-8 * scale);
        }
        // Unstructured v must be rejected (m > n ⇒ a.s. not in rowspace).
        let v_bad: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
        assert!(RvbSolver::default().solve(&s, &v_bad, lambda).is_err());
    }
}

#[test]
fn p7_complex_reduces_to_real() {
    let mut rng = Rng::seed_from(9005);
    for _ in 0..15 {
        let n = 2 + rng.below(8);
        let m = n + rng.below(30);
        let lambda = 0.1 + rng.uniform();
        let sr = Mat::randn(n, m, &mut rng);
        let sc = CMat::from_fn(n, m, |i, j| c64::from_re(sr[(i, j)]));
        let v: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
        let vc: Vec<c64> = v.iter().map(|&x| c64::from_re(x)).collect();
        let xc = solve_sr_complex(&sc, &vc, lambda).unwrap();
        let xr = CholSolver::default().solve(&sr, &v, lambda).unwrap();
        for (a, b) in xc.iter().zip(&xr) {
            assert!((a.re - b).abs() < 1e-8);
            assert!(a.im.abs() < 1e-8);
        }
    }
}

/// Apply the same rotation a session performs to a plain matrix: drop
/// `removed` rows (any order), append the rows of `added`.
fn rotate_rows(s: &Mat, removed: &[usize], added: &Mat) -> Mat {
    let (n, m) = s.shape();
    let kept: Vec<usize> = (0..n).filter(|i| !removed.contains(i)).collect();
    let mut out = Mat::zeros(kept.len() + added.rows(), m);
    for (i, &oi) in kept.iter().enumerate() {
        out.row_mut(i).copy_from_slice(s.row(oi));
    }
    for j in 0..added.rows() {
        out.row_mut(kept.len() + j).copy_from_slice(added.row(j));
    }
    out
}

#[test]
fn p9_streaming_rotation_matches_fresh_factor_across_threads_and_tiers() {
    let mut rng = Rng::seed_from(9009);
    let tiers = dngd::linalg::KernelIsa::supported_tiers();
    for case in 0..8 {
        let n = 6 + rng.below(40);
        let m = n + 10 + rng.below(100);
        let k_del = 1 + rng.below(n.min(5));
        let k_add = 1 + rng.below(5);
        let lambda = 10f64.powf(rng.uniform() * 3.0 - 3.0); // 1e-3 … 1
        let s = Mat::randn(n, m, &mut rng);
        let added = Mat::randn(k_add, m, &mut rng);
        // k distinct removal indices, deliberately unsorted.
        let mut removed: Vec<usize> = Vec::new();
        while removed.len() < k_del {
            let r = rng.below(n);
            if !removed.contains(&r) {
                removed.push(r);
            }
        }
        let rotated = rotate_rows(&s, &removed, &added);
        let v: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
        for &threads in &[1usize, 2, 4, 8] {
            for &isa in &tiers {
                let cfg = KernelConfig::with_threads(threads).with_isa(Some(isa));
                let mut fact = CholFactor::from_window(s.clone(), cfg);
                fact.redamp(lambda).unwrap();
                fact.update_rows(&removed, &added).unwrap();
                // Factor agreement ≤ 1e-9 against a cold gram_factor of
                // the rotated window (the PR-5 acceptance bar).
                let cold_l = CholSolver::with_config(cfg).gram_factor(&rotated, lambda).unwrap();
                let warm_l = fact.cached_factor().expect("rotated session stays damped");
                assert_eq!(warm_l.shape(), cold_l.shape());
                let scale = cold_l.max_abs().max(1.0);
                for i in 0..cold_l.rows() {
                    for j in 0..=i {
                        assert!(
                            (warm_l[(i, j)] - cold_l[(i, j)]).abs() < 1e-9 * scale,
                            "case {case} threads={threads} isa={isa}: factor ({i},{j}): {} vs {}",
                            warm_l[(i, j)],
                            cold_l[(i, j)]
                        );
                    }
                }
                // And the full operator agrees on a solve.
                let x = fact.solve(&v).unwrap();
                let res = residual_norm(&rotated, &x, &v, lambda);
                let fro = rotated.fro_norm();
                let sc = fro * fro * dngd::linalg::mat::norm2(&x)
                    + dngd::linalg::mat::norm2(&v);
                assert!(
                    res < 1e-9 * sc.max(1.0),
                    "case {case} threads={threads} isa={isa}: residual {res}"
                );
            }
        }
    }
}

#[test]
fn p10_streaming_append_breakdown_falls_back_to_full_refactor() {
    use dngd::linalg::kernel::counters;
    // λ = 1e-9 with an appended row that duplicates a window row: the
    // bordered pivot is δ² ≈ 2λ, so δ²/d ≈ 2λ/‖row‖² ≈ 3e-11 sits
    // below the session's 1e-10 relative floor — deterministically a
    // "breakdown" — while the full refactor of the patched Gram
    // succeeds robustly (its pivot ≈ 2e-9 ≫ rounding). The fallback is
    // observable: a pure rotation never invokes the Cholesky
    // front-end, the fallback does exactly once.
    let mut rng = Rng::seed_from(9010);
    let (n, m) = (24usize, 60usize);
    let lambda = 1e-9;
    let s = Mat::randn(n, m, &mut rng);
    let mut fact = CholFactor::from_window(s.clone(), KernelConfig::serial());
    fact.redamp(lambda).unwrap();

    // Control: a benign rotation is Cholesky-silent.
    let benign = Mat::randn(1, m, &mut rng);
    let chol0 = counters::cholesky_calls();
    fact.update_rows(&[0], &benign).unwrap();
    assert_eq!(
        counters::cholesky_calls() - chol0,
        0,
        "benign rotation must be a pure O(kn²) factor rotation"
    );

    // Breakdown: append a duplicate of a current window row.
    let dup = {
        let cur = fact.score().row(3).to_vec();
        let mut d = Mat::zeros(1, m);
        d.row_mut(0).copy_from_slice(&cur);
        d
    };
    let window_before = fact.score().clone();
    let chol1 = counters::cholesky_calls();
    fact.update_rows(&[0], &dup).unwrap();
    assert_eq!(
        counters::cholesky_calls() - chol1,
        1,
        "sub-floor bordered pivot must fall back to one full refactor"
    );
    // …and the fallback result still solves the rotated system. (At
    // λ = 1e-9 on a deliberately singular Gram, κ ≈ ‖G‖/λ ~ 1e10
    // amplifies last-bit Gram differences between the patched and
    // re-formed products, so the meaningful gate is backward error —
    // not elementwise agreement with an equally-rounded cold solve.)
    let rotated = rotate_rows(&window_before, &[0], &dup);
    let v: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
    let warm = fact.solve(&v).unwrap();
    let res = residual_norm(&rotated, &warm, &v, lambda);
    let fro = rotated.fro_norm();
    let scale = fro * fro * dngd::linalg::mat::norm2(&warm) + dngd::linalg::mat::norm2(&v);
    assert!(res < 1e-6 * scale.max(1.0), "fallback residual {res} (scale {scale:.3e})");
}

#[test]
fn p8_sharded_equals_serial_random_topologies() {
    let mut rng = Rng::seed_from(9006);
    for _ in 0..12 {
        let (s, v, lambda) = random_problem(&mut rng);
        let workers = 1 + rng.below(7);
        let depth = 1 + rng.below(4);
        let sharded = ShardedCholSolver::new(workers, depth);
        let x_d = sharded.solve_distributed(&s, &v, lambda).unwrap();
        let x_s = CholSolver::default().solve(&s, &v, lambda).unwrap();
        let scale = x_s.iter().map(|a| a.abs()).fold(0.0f64, f64::max).max(1.0);
        for (a, b) in x_d.iter().zip(&x_s) {
            assert!((a - b).abs() < 1e-9 * scale, "workers={workers} depth={depth}");
        }
    }
}
