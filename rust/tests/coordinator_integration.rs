//! Integration tests across the coordinator stack: distributed solves
//! under stress topologies, fault injection, backpressure under load,
//! and full-config trainer wiring.

use dngd::config::Config;
use dngd::coordinator::pool::{Job, WorkerPool};
use dngd::coordinator::trainer::{OptimizerChoice, TRAIN_LOG_COLUMNS};
use dngd::coordinator::{ShardPlan, ShardedCholSolver, Trainer};
use dngd::data::rng::Rng;
use dngd::linalg::Mat;
use dngd::metrics::MetricsLog;
use dngd::serve::transport::{ShardRequest, ShardResponse};
use dngd::solver::{residual_norm, CholSolver, DampedSolver};
use std::sync::mpsc::channel;

#[test]
fn distributed_solve_with_stragglers_still_correct() {
    let mut rng = Rng::seed_from(600);
    let solver = ShardedCholSolver::new(4, 2);
    let s = Mat::randn(12, 64, &mut rng);
    let v: Vec<f64> = (0..64).map(|_| rng.normal()).collect();
    let x = solver.solve_distributed(&s, &v, 0.1).unwrap();
    assert!(residual_norm(&s, &x, &v, 0.1) < 1e-8);
    let serial = CholSolver::default().solve(&s, &v, 0.1).unwrap();
    for (a, b) in x.iter().zip(&serial) {
        assert!((a - b).abs() < 1e-9);
    }
}

#[test]
fn sharded_solve_many_matches_serial_session_in_one_round_trip() {
    // PR-5 bugfix: ShardedFactor used to inherit the default
    // solve_many, paying k full Matvec/Apply round-trips for a k-RHS
    // block. The batched path must (a) agree with the serial session
    // and (b) cost exactly one MatvecMany + one ApplyMany message per
    // worker — pinned via the pool's processed-job counts.
    let mut rng = Rng::seed_from(604);
    let (n, m, k) = (12usize, 96usize, 5usize);
    let s = Mat::randn(n, m, &mut rng);
    let vs = Mat::randn(k, m, &mut rng);
    let sharded = ShardedCholSolver::new(3, 2);
    let serial = CholSolver::default();
    {
        let mut fd = sharded.factor(&s, 0.05).unwrap();
        let mut fs = serial.factor(&s, 0.05).unwrap();
        let xd = fd.solve_many(&vs).unwrap();
        let xs = fs.solve_many(&vs).unwrap();
        assert_eq!(xd.shape(), (k, m));
        for r in 0..k {
            for j in 0..m {
                assert!(
                    (xd[(r, j)] - xs[(r, j)]).abs() < 1e-9,
                    "rhs {r} col {j}: {} vs {}",
                    xd[(r, j)],
                    xs[(r, j)]
                );
            }
        }
    }
    // Per worker: SetShard + Gram + MatvecMany + ApplyMany + DropShard
    // (the factor's Drop, since PR 7 sessions are sid-keyed) + the
    // shutdown drain's Flush barrier + Shutdown = 7 jobs. The pre-fix
    // solve_many default would have cost 2 extra jobs per extra RHS.
    let counts = sharded.shutdown();
    assert_eq!(counts.len(), 3);
    assert!(
        counts.iter().all(|&c| c == 7),
        "k-RHS solve must be one batched round-trip per phase, got job counts {counts:?}"
    );
}

#[test]
fn pool_survives_many_small_jobs_under_backpressure() {
    let mut rng = Rng::seed_from(601);
    let pool = WorkerPool::spawn(3, 1); // minimal queue: max pressure
    let shard = Mat::randn(6, 10, &mut rng);
    for w in 0..3 {
        let (tx, rx) = channel();
        pool.send(w, Job::Request {
            req: ShardRequest::SetShard { sid: 1, shard: shard.clone() },
            reply: tx,
        })
        .unwrap();
        assert_eq!(rx.recv().unwrap(), ShardResponse::Ack);
        let (tx, _rx) = channel();
        pool.send(w, Job::Request { req: ShardRequest::Stall { ms: 1 }, reply: tx }).unwrap();
    }
    let expect = shard.matvec(&vec![1.0; 10]);
    let mut waits = Vec::with_capacity(150);
    for _round in 0..50 {
        for w in 0..3 {
            let (tx, rx) = channel();
            pool.send(w, Job::Request {
                req: ShardRequest::MatvecMany { sid: 1, v_k: Mat::from_vec(1, 10, vec![1.0; 10]) },
                reply: tx,
            })
            .unwrap();
            waits.push(rx);
        }
    }
    let mut count = 0;
    for rx in waits {
        match rx.recv().unwrap() {
            ShardResponse::Mat(u) => {
                assert_eq!(u.shape(), (6, 1));
                for (i, b) in expect.iter().enumerate() {
                    assert!((u[(i, 0)] - b).abs() < 1e-12);
                }
            }
            other => panic!("unexpected response {other:?}"),
        }
        count += 1;
    }
    assert_eq!(count, 150);
    let processed = pool.shutdown();
    // Every worker processed SetShard + Stall + 50 matvecs + the
    // shutdown drain's Flush barrier + Shutdown.
    assert!(processed.iter().all(|&c| c == 54), "{processed:?}");
}

#[test]
fn sharded_solver_shared_across_leader_threads() {
    let mut rng = Rng::seed_from(602);
    let solver = std::sync::Arc::new(ShardedCholSolver::new(4, 4));
    let s = std::sync::Arc::new(Mat::randn(10, 80, &mut rng));
    let v: Vec<f64> = (0..80).map(|_| rng.normal()).collect();
    let serial = CholSolver::default().solve(&s, &v, 0.3).unwrap();
    let mut handles = vec![];
    for _ in 0..4 {
        let solver = solver.clone();
        let s = s.clone();
        let v = v.clone();
        let serial = serial.clone();
        handles.push(std::thread::spawn(move || {
            let x = solver.solve_distributed(&s, &v, 0.3).unwrap();
            for (a, b) in x.iter().zip(&serial) {
                assert!((a - b).abs() < 1e-9);
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
}

#[test]
fn shard_plan_owner_round_trips_with_slicing() {
    let mut rng = Rng::seed_from(603);
    let s = Mat::randn(5, 57, &mut rng);
    let plan = ShardPlan::balanced(57, 7);
    let mut rebuilt: Option<Mat> = None;
    for &(c0, c1) in &plan.ranges {
        let shard = s.slice_cols(c0, c1);
        rebuilt = Some(match rebuilt {
            None => shard,
            Some(acc) => Mat::hstack(&acc, &shard),
        });
    }
    assert_eq!(rebuilt.unwrap(), s);
}

#[test]
fn trainer_from_config_file_and_overrides() {
    let cfg = Config::from_toml_str(
        r#"
[model]
dim = 8
heads = 2
layers = 1
context = 8
mlp_hidden = 16

[train]
steps = 3
batch_size = 8
corpus_len = 3000

[coordinator]
workers = 2
use_artifacts = false
"#,
        &["train.steps=2".into()],
    )
    .unwrap();
    assert_eq!(cfg.train.steps, 2); // override wins
    let mut trainer = Trainer::new(&cfg, OptimizerChoice::Ngd).unwrap();
    let mut log = MetricsLog::new(TRAIN_LOG_COLUMNS);
    let report = trainer.run(&mut log).unwrap();
    assert_eq!(report.steps, 2);
    assert!(report.final_loss.is_finite());
}

#[test]
fn adaptive_damping_reacts_to_loss() {
    let cfg = Config::from_toml_str(
        r#"
[model]
dim = 8
heads = 2
layers = 1
context = 8
mlp_hidden = 16

[train]
steps = 6
batch_size = 8
corpus_len = 3000
learning_rate = 0.3

[solver]
lambda = 0.1
adaptive = true

[coordinator]
workers = 1
use_artifacts = false
"#,
        &[],
    )
    .unwrap();
    let mut trainer = Trainer::new(&cfg, OptimizerChoice::Ngd).unwrap();
    let mut log = MetricsLog::new(TRAIN_LOG_COLUMNS);
    trainer.run(&mut log).unwrap();
    let lambdas = log.column("lambda").unwrap();
    assert!(lambdas.iter().any(|&l| (l - 0.1).abs() > 1e-12), "λ never adapted: {lambdas:?}");
}
