//! Property tests for the PR-4 SIMD dispatch tier.
//!
//! Invariants checked:
//!  I1. Every supported ISA tier's packed `dgemm` matches the naive
//!      oracle over the edge-shape grid m,n,k ∈ {1, 3, MR±1, NR±1, 63,
//!      64, 65} in all three storage layouts (N/N, N/T, T/N).
//!  I2. Within every tier, `dgemm_threaded` is bit-identical to the
//!      serial driver at thread counts 1/2/4/8 (the amended PR-4
//!      determinism contract: bit-identity holds *within* a tier; the
//!      tier is re-established inside every pool job).
//!  I3. Within every tier, threaded SYRK / Cholesky / multi-RHS TRSM
//!      are bit-identical to their serial counterparts, and SYRK
//!      matches the seed scalar reference (`gemm::reference`) to
//!      tolerance.
//!  I4. A chol session pinned to a tier via `solver.isa` produces
//!      bit-identical output to the same session run under a
//!      `with_isa` scope of that tier, and stays tolerance-equal to
//!      the scalar tier.
//!
//! The CI job that exports `DNGD_KERNEL=scalar` runs this whole file
//! (and the rest of the suite) with the process default forced to the
//! fallback tier, which keeps the scalar path from rotting.

use dngd::data::rng::Rng;
use dngd::linalg::gemm::{self, reference};
use dngd::linalg::kernel::{self, Trans, MC, MR, NR};
use dngd::linalg::{
    cholesky_threaded, solve_lower_multi_threaded, solve_lower_transpose_multi_threaded, with_isa,
    KernelIsa, Mat,
};
use dngd::solver::{SolverKind, SolverOptions, SolverRegistry};

fn naive_gemm(a: &Mat, b: &Mat) -> Mat {
    let (p, q) = a.shape();
    let (_, r) = b.shape();
    Mat::from_fn(p, r, |i, j| (0..q).map(|k| a[(i, k)] * b[(k, j)]).sum())
}

/// The satellite edge-shape grid: 1, 3, MR±1, NR±1, 63, 64, 65.
fn edge_dims() -> Vec<usize> {
    let mut dims = vec![1, 3, MR - 1, MR + 1, NR - 1, NR + 1, 63, 64, 65];
    dims.dedup();
    dims
}

#[test]
fn i1_every_tier_matches_naive_on_edge_shapes_all_layouts() {
    let mut rng = Rng::seed_from(9100);
    let dims = edge_dims();
    for &isa in &KernelIsa::supported_tiers() {
        // One representative per (m-class, n-class, k-class) diagonal
        // sweep of the full grid keeps the cross product bounded while
        // still hitting every dim in every role.
        for (ti, &m) in dims.iter().enumerate() {
            let n = dims[(ti + 3) % dims.len()];
            let k = dims[(ti + 6) % dims.len()];
            let a = Mat::randn(m, k, &mut rng);
            let b = Mat::randn(k, n, &mut rng);
            let expect = naive_gemm(&a, &b);
            let tol = 1e-11 * (k as f64).max(1.0);
            with_isa(isa, || {
                let mut c = Mat::zeros(m, n);
                gemm::gemm(1.0, &a, &b, 0.0, &mut c);
                let bt = b.transpose();
                let mut cnt = Mat::zeros(m, n);
                gemm::gemm_nt(1.0, &a, &bt, 0.0, &mut cnt);
                let at = a.transpose();
                let mut ctn = Mat::zeros(m, n);
                gemm::gemm_tn(1.0, &at, &b, 0.0, &mut ctn);
                for i in 0..m {
                    for j in 0..n {
                        let want = expect[(i, j)];
                        assert!(
                            (c[(i, j)] - want).abs() < tol,
                            "[{isa}] gemm ({m},{n},{k}) at ({i},{j})"
                        );
                        assert!(
                            (cnt[(i, j)] - want).abs() < tol,
                            "[{isa}] gemm_nt ({m},{n},{k}) at ({i},{j})"
                        );
                        assert!(
                            (ctn[(i, j)] - want).abs() < tol,
                            "[{isa}] gemm_tn ({m},{n},{k}) at ({i},{j})"
                        );
                    }
                }
            });
        }
    }
}

#[test]
fn i2_threaded_gemm_bit_identical_within_every_tier() {
    let mut rng = Rng::seed_from(9200);
    // ≥ 2 MC bands and above the threaded-dispatch FLOP floor, every
    // dim off the blocking grid.
    let (m, n, k) = (2 * MC + 9, 8 * NR + 3, 129);
    let a = Mat::randn(m, k, &mut rng);
    let b = Mat::randn(k, n, &mut rng);
    let c0 = Mat::randn(m, n, &mut rng);
    for &isa in &KernelIsa::supported_tiers() {
        with_isa(isa, || {
            let mut serial = c0.clone();
            kernel::dgemm(
                m,
                n,
                k,
                1.5,
                a.as_slice(),
                k,
                Trans::N,
                b.as_slice(),
                n,
                Trans::N,
                0.5,
                serial.as_mut_slice(),
                n,
            );
            for threads in [1usize, 2, 4, 8] {
                let mut c = c0.clone();
                kernel::dgemm_threaded(
                    m,
                    n,
                    k,
                    1.5,
                    a.as_slice(),
                    k,
                    Trans::N,
                    b.as_slice(),
                    n,
                    Trans::N,
                    0.5,
                    c.as_mut_slice(),
                    n,
                    threads,
                );
                assert_eq!(
                    c.as_slice(),
                    serial.as_slice(),
                    "[{isa}] dgemm_threaded at {threads} threads differs from serial"
                );
            }
        });
    }
}

#[test]
fn i3_syrk_cholesky_trsm_bit_identical_within_every_tier() {
    let mut rng = Rng::seed_from(9300);
    let (n, m, k) = (MC + 37, 300usize, 13usize);
    let s = Mat::randn(n, m, &mut rng);
    let bmat = Mat::randn(n, k, &mut rng);
    let scalar_ref = reference::syrk_scalar(&s, 0.5);
    for &isa in &KernelIsa::supported_tiers() {
        with_isa(isa, || {
            // SYRK: serial vs threaded bit-identity, and the seed scalar
            // oracle to tolerance (cross-tier is only tolerance-equal).
            let w = gemm::syrk(&s, 0.5);
            for threads in [1usize, 2, 4, 8] {
                let wp = gemm::syrk_parallel(&s, 0.5, threads);
                assert_eq!(
                    wp.as_slice(),
                    w.as_slice(),
                    "[{isa}] syrk_parallel at {threads} threads differs from serial"
                );
            }
            let scale = scalar_ref.max_abs().max(1.0);
            for i in 0..n {
                for j in 0..n {
                    assert!(
                        (w[(i, j)] - scalar_ref[(i, j)]).abs() < 1e-11 * scale,
                        "[{isa}] syrk vs scalar reference at ({i},{j})"
                    );
                }
            }
            // Cholesky of the (SPD) Gram: threaded ≡ serial, bitwise.
            let l = cholesky_threaded(&w, 1).unwrap();
            for threads in [2usize, 4, 8] {
                let lt = cholesky_threaded(&w, threads).unwrap();
                assert_eq!(
                    lt.as_slice(),
                    l.as_slice(),
                    "[{isa}] cholesky at {threads} threads differs from serial"
                );
            }
            // Multi-RHS TRSM pair: threaded ≡ serial, bitwise.
            let y = solve_lower_multi_threaded(&l, &bmat, 1);
            let z = solve_lower_transpose_multi_threaded(&l, &y, 1);
            for threads in [2usize, 4, 8] {
                let yt = solve_lower_multi_threaded(&l, &bmat, threads);
                let zt = solve_lower_transpose_multi_threaded(&l, &yt, threads);
                assert_eq!(
                    zt.as_slice(),
                    z.as_slice(),
                    "[{isa}] trsm at {threads} threads differs from serial"
                );
            }
        });
    }
}

#[test]
fn i4_solver_isa_option_pins_the_session_tier() {
    let mut rng = Rng::seed_from(9400);
    let (n, m, k) = (96usize, 320usize, 5usize);
    let s = Mat::randn(n, m, &mut rng);
    let vs = Mat::randn(k, m, &mut rng);
    let session_with_opts = |isa: Option<KernelIsa>| -> Mat {
        let mut opts = SolverOptions::default();
        if let Some(isa) = isa {
            opts.apply("isa", isa.as_str()).unwrap();
        }
        let reg = SolverRegistry::new(opts);
        let plan = reg.plan(SolverKind::Chol, n, m);
        let mut fact = plan.factor(&s, 1e-2).unwrap();
        fact.solve_many(&vs).unwrap()
    };
    let scalar = session_with_opts(Some(KernelIsa::Scalar));
    for &isa in &KernelIsa::supported_tiers() {
        // solver.isa = tier  ≡  the whole default session under with_isa.
        let via_option = session_with_opts(Some(isa));
        let via_scope = with_isa(isa, || session_with_opts(None));
        assert_eq!(
            via_option.as_slice(),
            via_scope.as_slice(),
            "[{isa}] solver.isa and with_isa disagree"
        );
        // Cross-tier: tolerance-equal to the scalar tier, and correct.
        let scale = scalar.max_abs().max(1.0);
        for i in 0..k {
            for j in 0..m {
                assert!(
                    (via_option[(i, j)] - scalar[(i, j)]).abs() < 1e-7 * scale,
                    "[{isa}] vs scalar tier at ({i},{j})"
                );
            }
        }
        let res = dngd::solver::residual_norm(&s, via_option.row(0), vs.row(0), 1e-2);
        let rscale = s.fro_norm().powi(2) * dngd::linalg::mat::norm2(via_option.row(0))
            + dngd::linalg::mat::norm2(vs.row(0));
        assert!(res < 1e-9 * rscale.max(1.0), "[{isa}] residual {res}");
    }
}
