//! Integration: the Rust PJRT runtime executes the AOT artifacts that
//! `python/compile/aot.py` lowered from the L2 JAX graphs (which inline
//! the L1 Pallas kernels), and the numbers match the native Rust solver.
//!
//! These tests skip (with a notice) when `make artifacts` has not run —
//! a fresh checkout stays green, CI with artifacts gets full coverage.

use dngd::data::rng::Rng;
use dngd::linalg::Mat;
use dngd::runtime::{ArtifactKind, ArtifactRegistry, Backend, PjrtSolver};
use dngd::solver::{residual_norm, CholSolver, DampedSolver};
use std::path::Path;

fn registry() -> ArtifactRegistry {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    ArtifactRegistry::scan(&dir)
}

macro_rules! require_artifact {
    ($reg:expr, $n:expr, $m:expr) => {
        match $reg.find(ArtifactKind::Solve, $n, $m) {
            Some(p) => p,
            None => {
                eprintln!(
                    "SKIP: artifact solve_n{}_m{} not found — run `make artifacts`",
                    $n, $m
                );
                return;
            }
        }
    };
}

#[test]
fn pjrt_solve_matches_native() {
    let reg = registry();
    let path = require_artifact!(reg, 8, 32);
    let solver = PjrtSolver::load(&path, 8, 32).expect("compile artifact");
    let mut rng = Rng::seed_from(500);
    let s = Mat::randn(8, 32, &mut rng);
    let v: Vec<f64> = (0..32).map(|_| rng.normal()).collect();
    for lambda in [1.0, 0.1, 1e-2] {
        let x_pjrt = solver.solve(&s, &v, lambda).unwrap();
        let x_native = CholSolver::default().solve(&s, &v, lambda).unwrap();
        // Artifact runs in f32: compare at f32-appropriate tolerance,
        // relative to the solution scale (which grows as 1/λ).
        let scale = x_native.iter().fold(0.0f64, |a, x| a.max(x.abs())).max(1.0);
        for (a, b) in x_pjrt.iter().zip(&x_native) {
            assert!(
                (a - b).abs() < 1e-3 * scale,
                "λ={lambda}: pjrt {a} vs native {b} (scale {scale})"
            );
        }
        // And the residual itself must be small in the same scale.
        let r = residual_norm(&s, &x_pjrt, &v, lambda);
        assert!(r < 1e-2 * scale, "λ={lambda}: residual {r}");
    }
}

#[test]
fn pjrt_solver_rejects_wrong_shapes() {
    let reg = registry();
    let path = require_artifact!(reg, 8, 32);
    let solver = PjrtSolver::load(&path, 8, 32).unwrap();
    let mut rng = Rng::seed_from(501);
    let s_wrong = Mat::randn(8, 33, &mut rng);
    let v = vec![0.0; 33];
    assert!(solver.solve(&s_wrong, &v, 0.1).is_err());
}

#[test]
fn backend_selects_pjrt_when_artifact_exists() {
    let reg = registry();
    let _ = require_artifact!(reg, 8, 32);
    let b = Backend::select(&reg, 8, 32, 1);
    assert_eq!(b.name(), "pjrt");
    // Unknown shape falls back.
    let b2 = Backend::select(&reg, 9, 31, 1);
    assert_eq!(b2.name(), "native");
}

#[test]
fn pjrt_solve_repeated_calls_stable() {
    // The executable is compiled once and reused; repeated execution must
    // not leak or drift.
    let reg = registry();
    let path = require_artifact!(reg, 8, 32);
    let solver = PjrtSolver::load(&path, 8, 32).unwrap();
    let mut rng = Rng::seed_from(502);
    let s = Mat::randn(8, 32, &mut rng);
    let v: Vec<f64> = (0..32).map(|_| rng.normal()).collect();
    let first = solver.solve(&s, &v, 0.5).unwrap();
    for _ in 0..10 {
        let again = solver.solve(&s, &v, 0.5).unwrap();
        assert_eq!(first, again, "PJRT execution must be deterministic");
    }
}
