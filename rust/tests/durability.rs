//! Crash-durability integration tests (PR 9).
//!
//! The contract under test: a training run killed at **any** step
//! boundary and resumed from its latest durable checkpoint rejoins the
//! unfailed trajectory bit-identically — same parameters to the last
//! mantissa bit. That requires the checkpoint to carry the complete
//! state: params, optimizer momentum, the damping scalar, the batch-RNG
//! data cursor, and (in streaming mode) a replayable log of the owned
//! window session's rotations and λ-backoff chains.
//!
//! The matrix crosses every kill boundary with the solve modes that
//! carry distinct durable state: classic sharded chol, streaming-window
//! chol and rvb, and the mixed-precision (f32 factor + f64 latch)
//! paths. Recovery-robustness tests (corrupt → quarantine, truncation,
//! version skew) ride along at the trainer level.

use dngd::checkpoint::Checkpoint;
use dngd::config::Config;
use dngd::coordinator::trainer::{OptimizerChoice, TRAIN_LOG_COLUMNS};
use dngd::coordinator::Trainer;
use dngd::metrics::MetricsLog;
use dngd::solver::{Precision, SolverKind};
use std::path::PathBuf;

const STEPS: usize = 6;
const CHECKPOINT_EVERY: usize = 2;

fn scratch(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("dngd_durability_{}_{tag}", std::process::id()))
}

fn base_cfg(dir: &std::path::Path) -> Config {
    let mut cfg = Config::from_toml_str(
        r#"
[model]
dim = 8
heads = 2
layers = 1
context = 8
mlp_hidden = 16

[train]
steps = 6
batch_size = 16
learning_rate = 0.3
corpus_len = 4000
seed = 11
checkpoint_every = 2

[solver]
lambda = 0.01

[coordinator]
workers = 1
use_artifacts = false
"#,
        &[],
    )
    .unwrap();
    cfg.train.checkpoint_dir = dir.to_string_lossy().to_string();
    cfg
}

struct Mode {
    name: &'static str,
    mutate: fn(&mut Config),
}

const MODES: &[Mode] = &[
    Mode {
        name: "classic_chol_sharded",
        mutate: |cfg| {
            cfg.coordinator.workers = 2;
        },
    },
    Mode {
        name: "windowed_chol",
        mutate: |cfg| {
            cfg.solver.window = 48;
            cfg.solver.refresh_every = 3;
        },
    },
    Mode {
        name: "windowed_rvb",
        mutate: |cfg| {
            cfg.solver.kind = SolverKind::Rvb;
            cfg.solver.window = 48;
            cfg.solver.refresh_every = 3;
        },
    },
    Mode {
        name: "mixed_classic",
        mutate: |cfg| {
            cfg.solver.precision = Precision::Mixed;
        },
    },
    Mode {
        name: "mixed_windowed",
        mutate: |cfg| {
            cfg.solver.precision = Precision::Mixed;
            cfg.solver.window = 48;
            cfg.solver.refresh_every = 3;
        },
    },
];

fn mode_cfg(mode: &Mode, dir: &std::path::Path) -> Config {
    let mut cfg = base_cfg(dir);
    (mode.mutate)(&mut cfg);
    cfg.validate().unwrap();
    cfg
}

fn run_to_completion(cfg: &Config) -> Vec<f64> {
    let mut t = Trainer::new(cfg, OptimizerChoice::Ngd).unwrap();
    let mut log = MetricsLog::new(TRAIN_LOG_COLUMNS);
    let report = t.run(&mut log).unwrap();
    assert_eq!(report.steps, STEPS);
    t.params.clone()
}

fn assert_bits_equal(reference: &[f64], got: &[f64], what: &str) {
    assert_eq!(reference.len(), got.len(), "{what}: param count");
    for (j, (a, b)) in reference.iter().zip(got).enumerate() {
        assert_eq!(
            a.to_bits(),
            b.to_bits(),
            "{what}: param {j} diverged ({a:e} vs {b:e})"
        );
    }
}

/// Kill at every step boundary 1..STEPS and resume a fresh trainer each
/// time; the completed trajectory must match the unfailed reference bit
/// for bit. A kill before the first checkpoint (boundary 1) resumes
/// from nothing and restarts fresh — the degenerate case is covered too.
fn kill_everywhere(mode: &Mode) {
    let dir = scratch(mode.name);
    std::fs::remove_dir_all(&dir).ok();
    let cfg = mode_cfg(mode, &dir);
    let reference = run_to_completion(&cfg);
    std::fs::remove_dir_all(&dir).ok();

    for kill_at in 1..STEPS {
        std::fs::remove_dir_all(&dir).ok();
        let mut killed = Trainer::new(&cfg, OptimizerChoice::Ngd).unwrap();
        let mut log = MetricsLog::new(TRAIN_LOG_COLUMNS);
        killed.run_partial(&mut log, kill_at).unwrap();
        drop(killed); // kill -9 at the boundary

        let mut resumed = Trainer::new(&cfg, OptimizerChoice::Ngd).unwrap();
        let at = resumed.resume_latest().unwrap();
        let expected = (kill_at / CHECKPOINT_EVERY * CHECKPOINT_EVERY > 0)
            .then_some(kill_at / CHECKPOINT_EVERY * CHECKPOINT_EVERY);
        assert_eq!(
            at, expected,
            "{}: kill@{kill_at} must resume from the latest durable boundary",
            mode.name
        );
        let mut log2 = MetricsLog::new(TRAIN_LOG_COLUMNS);
        let report = resumed.run(&mut log2).unwrap();
        assert_eq!(report.steps, STEPS);
        assert_bits_equal(
            &reference,
            &resumed.params,
            &format!("{} kill@{kill_at}", mode.name),
        );
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn kill_anywhere_classic_chol_sharded() {
    kill_everywhere(&MODES[0]);
}

#[test]
fn kill_anywhere_windowed_chol() {
    kill_everywhere(&MODES[1]);
}

#[test]
fn kill_anywhere_windowed_rvb() {
    kill_everywhere(&MODES[2]);
}

#[test]
fn kill_anywhere_mixed_classic() {
    kill_everywhere(&MODES[3]);
}

#[test]
fn kill_anywhere_mixed_windowed() {
    kill_everywhere(&MODES[4]);
}

/// Consecutive `run_partial` segments on one live trainer must also
/// compose into the reference trajectory (the armed continuation path,
/// no disk round-trip at all).
#[test]
fn partial_runs_compose_bit_identically() {
    let dir = scratch("compose");
    std::fs::remove_dir_all(&dir).ok();
    let cfg = mode_cfg(&MODES[1], &dir); // windowed chol: hardest state
    let reference = run_to_completion(&cfg);
    std::fs::remove_dir_all(&dir).ok();

    let mut t = Trainer::new(&cfg, OptimizerChoice::Ngd).unwrap();
    for seg in [1usize, 2, 3] {
        let mut log = MetricsLog::new(TRAIN_LOG_COLUMNS);
        t.run_partial(&mut log, seg).unwrap();
    }
    assert_bits_equal(&reference, &t.params, "1+2+3 step segments");
    std::fs::remove_dir_all(&dir).ok();
}

/// A truncated checkpoint (torn write survived by a weaker filesystem)
/// is quarantined, and recovery falls back to the previous boundary —
/// still bit-identical.
#[test]
fn truncated_checkpoint_is_quarantined_and_recovery_falls_back() {
    let dir = scratch("truncate");
    std::fs::remove_dir_all(&dir).ok();
    let cfg = mode_cfg(&MODES[0], &dir);
    let reference = run_to_completion(&cfg);
    std::fs::remove_dir_all(&dir).ok();

    let mut killed = Trainer::new(&cfg, OptimizerChoice::Ngd).unwrap();
    let mut log = MetricsLog::new(TRAIN_LOG_COLUMNS);
    killed.run_partial(&mut log, 5).unwrap(); // checkpoints at 2 and 4
    drop(killed);
    let p4 = dir.join("step_4.ckpt");
    let bytes = std::fs::read(&p4).unwrap();
    std::fs::write(&p4, &bytes[..bytes.len() / 3]).unwrap();

    let mut resumed = Trainer::new(&cfg, OptimizerChoice::Ngd).unwrap();
    assert_eq!(resumed.resume_latest().unwrap(), Some(2));
    assert_eq!(resumed.stats().quarantined, 1);
    assert!(dir.join("step_4.ckpt.corrupt").exists());
    assert!(!p4.exists());
    let mut log2 = MetricsLog::new(TRAIN_LOG_COLUMNS);
    resumed.run(&mut log2).unwrap();
    assert_bits_equal(&reference, &resumed.params, "truncated fallback");
    std::fs::remove_dir_all(&dir).ok();
}

/// A checkpoint from a future container format (healthy checksum, newer
/// version) is skipped *in place* — never quarantined, never loaded —
/// and recovery falls back to the newest same-generation checkpoint.
#[test]
fn version_skewed_checkpoint_is_skipped_in_place() {
    let dir = scratch("skew");
    std::fs::remove_dir_all(&dir).ok();
    let cfg = mode_cfg(&MODES[0], &dir);
    let mut t = Trainer::new(&cfg, OptimizerChoice::Ngd).unwrap();
    let mut log = MetricsLog::new(TRAIN_LOG_COLUMNS);
    t.run_partial(&mut log, 5).unwrap();
    drop(t);
    let p4 = dir.join("step_4.ckpt");
    let ck = Checkpoint::load(&p4).unwrap();
    std::fs::write(&p4, ck.to_bytes_with_version(Checkpoint::format_version() + 1)).unwrap();

    let mut resumed = Trainer::new(&cfg, OptimizerChoice::Ngd).unwrap();
    assert_eq!(resumed.resume_latest().unwrap(), Some(2));
    assert_eq!(resumed.stats().version_skipped, 1);
    assert_eq!(resumed.stats().quarantined, 0);
    assert!(p4.exists(), "skewed file must stay in place for the newer binary");
    std::fs::remove_dir_all(&dir).ok();
}
