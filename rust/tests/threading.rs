//! PR-3 determinism suite: every threaded kernel in the dense pipeline
//! must be **bit-identical** to its serial result for every thread
//! count. This is what makes `solver.threads` a pure throughput knob —
//! a training run, a λ-backoff trajectory, or a checkpoint produced at
//! 8 threads replays exactly at 1.
//!
//! Invariants checked (threads ∈ {1, 2, 4, 8} throughout):
//!  T1. `dgemm_threaded` ≡ `dgemm` bitwise for all four N/T layout
//!      pairs, with non-trivial alpha/beta and off-grid shapes.
//!  T2. `cholesky_in_place_threaded` (lookahead pipeline) ≡ serial
//!      bitwise, and still reconstructs `L·Lᵀ = W`.
//!  T3. The threaded multi-RHS TRSM pair ≡ serial bitwise, and matches
//!      per-column vector substitution numerically.
//!  T4. The threaded gemm/gemm_nt/gemm_tn front-ends ≡ serial bitwise.
//!  T5. A full chol session round-trip (`begin → redamp → solve_many →
//!      redamp → solve_many`) is bitwise reproducible across thread
//!      counts end-to-end.

use dngd::data::rng::Rng;
use dngd::linalg::kernel::{self, Trans};
use dngd::linalg::{
    cholesky_in_place_threaded, cholesky_threaded, gemm_nt_threaded, gemm_threaded,
    gemm_tn_threaded, solve_lower, solve_lower_multi_threaded, solve_lower_transpose,
    solve_lower_transpose_multi_threaded, syrk, Mat,
};
use dngd::solver::{CholSolver, DampedSolver};

const SWEEP: [usize; 4] = [1, 2, 4, 8];

#[test]
fn t1_dgemm_bit_identical_across_thread_counts_all_layouts() {
    let mut rng = Rng::seed_from(8101);
    // m spans several MC blocks with a ragged tail so the band split is
    // non-trivial; n/k sit off the NR/KC grids.
    let (m, n, k) = (5 * kernel::MC + 37, 67, kernel::KC + 19);
    let fill = |rows: usize, cols: usize, rng: &mut Rng| Mat::randn(rows, cols, rng);
    // Buffers for each storage layout: N stores the logical operand,
    // T stores its transpose.
    let a_n = fill(m, k, &mut rng);
    let a_t = a_n.transpose();
    let b_n = fill(k, n, &mut rng);
    let b_t = b_n.transpose();
    let c0 = fill(m, n, &mut rng);
    for (ta, tb) in [
        (Trans::N, Trans::N),
        (Trans::N, Trans::T),
        (Trans::T, Trans::N),
        (Trans::T, Trans::T),
    ] {
        let (a, lda) = match ta {
            Trans::N => (&a_n, k),
            Trans::T => (&a_t, m),
        };
        let (b, ldb) = match tb {
            Trans::N => (&b_n, n),
            Trans::T => (&b_t, k),
        };
        let mut reference = c0.clone();
        kernel::dgemm(
            m,
            n,
            k,
            1.25,
            a.as_slice(),
            lda,
            ta,
            b.as_slice(),
            ldb,
            tb,
            -0.5,
            reference.as_mut_slice(),
            n,
        );
        for threads in SWEEP {
            let mut c = c0.clone();
            kernel::dgemm_threaded(
                m,
                n,
                k,
                1.25,
                a.as_slice(),
                lda,
                ta,
                b.as_slice(),
                ldb,
                tb,
                -0.5,
                c.as_mut_slice(),
                n,
                threads,
            );
            assert_eq!(
                c.as_slice(),
                reference.as_slice(),
                "dgemm {ta:?}/{tb:?} at {threads} threads is not bit-identical to serial"
            );
        }
    }
}

#[test]
fn t2_cholesky_bit_identical_and_reconstructs() {
    let mut rng = Rng::seed_from(8102);
    // Several NB panels with a ragged tail, and enough trailing rows
    // past the lookahead slab for multiple MC strips.
    for &n in &[97usize, 300, 2 * kernel::MC + 61] {
        let w = syrk(&Mat::randn(n, n + 9, &mut rng), 1.0);
        let mut reference = w.clone();
        cholesky_in_place_threaded(&mut reference, 1).unwrap();
        for threads in SWEEP {
            let l = cholesky_threaded(&w, threads).unwrap();
            assert_eq!(
                l.as_slice(),
                reference.as_slice(),
                "cholesky n={n} at {threads} threads is not bit-identical to serial"
            );
        }
        // And the factor is right: L·Lᵀ = W.
        let mut recon = Mat::zeros(n, n);
        gemm_nt_threaded(1.0, &reference, &reference, 0.0, &mut recon, 4);
        let scale = w.max_abs().max(1.0);
        for i in 0..n {
            for j in 0..n {
                assert!(
                    (recon[(i, j)] - w[(i, j)]).abs() < 1e-9 * scale,
                    "LLᵀ mismatch n={n} ({i},{j})"
                );
            }
        }
    }
}

#[test]
fn t3_trsm_bit_identical_and_matches_columnwise() {
    let mut rng = Rng::seed_from(8103);
    for &(n, k) in &[(200usize, 23usize), (129, 8), (96, 3)] {
        let l = cholesky_threaded(&syrk(&Mat::randn(n, n + 5, &mut rng), 1.0), 1).unwrap();
        let b = Mat::randn(n, k, &mut rng);
        let y_ref = solve_lower_multi_threaded(&l, &b, 1);
        let z_ref = solve_lower_transpose_multi_threaded(&l, &y_ref, 1);
        for threads in SWEEP {
            let y = solve_lower_multi_threaded(&l, &b, threads);
            assert_eq!(
                y.as_slice(),
                y_ref.as_slice(),
                "fwd TRSM ({n},{k}) at {threads} threads differs from serial"
            );
            let z = solve_lower_transpose_multi_threaded(&l, &y, threads);
            assert_eq!(
                z.as_slice(),
                z_ref.as_slice(),
                "adj TRSM ({n},{k}) at {threads} threads differs from serial"
            );
        }
        // Numerical anchor: the blocked panels match per-column vector
        // substitution.
        for col in 0..k {
            let bcol = b.col(col);
            let ycol = solve_lower(&l, &bcol);
            let zcol = solve_lower_transpose(&l, &ycol);
            for i in 0..n {
                assert!((y_ref[(i, col)] - ycol[i]).abs() < 1e-9, "fwd ({n},{k}) ({i},{col})");
                assert!((z_ref[(i, col)] - zcol[i]).abs() < 1e-9, "adj ({n},{k}) ({i},{col})");
            }
        }
    }
}

#[test]
fn t4_gemm_front_ends_bit_identical() {
    let mut rng = Rng::seed_from(8104);
    let (p, q, r) = (3 * kernel::MC + 11, 150, 41);
    let a = Mat::randn(p, q, &mut rng);
    let b = Mat::randn(q, r, &mut rng);
    let c0 = Mat::randn(p, r, &mut rng);

    let mut nn_ref = c0.clone();
    gemm_threaded(2.0, &a, &b, 0.25, &mut nn_ref, 1);
    let bt = b.transpose();
    let mut nt_ref = c0.clone();
    gemm_nt_threaded(2.0, &a, &bt, 0.25, &mut nt_ref, 1);
    let at = a.transpose();
    let mut tn_ref = c0.clone();
    gemm_tn_threaded(2.0, &at, &b, 0.25, &mut tn_ref, 1);
    assert_eq!(nn_ref.as_slice(), nt_ref.as_slice(), "layout front-ends disagree");

    for threads in SWEEP {
        let mut c = c0.clone();
        gemm_threaded(2.0, &a, &b, 0.25, &mut c, threads);
        assert_eq!(c.as_slice(), nn_ref.as_slice(), "gemm at {threads} threads");
        let mut c = c0.clone();
        gemm_nt_threaded(2.0, &a, &bt, 0.25, &mut c, threads);
        assert_eq!(c.as_slice(), nt_ref.as_slice(), "gemm_nt at {threads} threads");
        let mut c = c0.clone();
        gemm_tn_threaded(2.0, &at, &b, 0.25, &mut c, threads);
        assert_eq!(c.as_slice(), tn_ref.as_slice(), "gemm_tn at {threads} threads");
    }
}

#[test]
fn t5_chol_session_round_trip_bit_identical_end_to_end() {
    let mut rng = Rng::seed_from(8105);
    let (n, m, k) = (200usize, 640usize, 8usize);
    let s = Mat::randn(n, m, &mut rng);
    let vs = Mat::randn(k, m, &mut rng);
    let run = |threads: usize| -> (Mat, Mat) {
        let solver = CholSolver::with_threads(threads);
        let mut fact = solver.begin(&s);
        fact.redamp(1e-2).unwrap();
        let x1 = fact.solve_many(&vs).unwrap();
        // λ-resweep on the cached Gram, then solve again — the full
        // consumer trajectory (optimizer backoff / LM retry).
        fact.redamp(1e-3).unwrap();
        let x2 = fact.solve_many(&vs).unwrap();
        (x1, x2)
    };
    let (x1_ref, x2_ref) = run(1);
    for threads in SWEEP {
        let (x1, x2) = run(threads);
        assert_eq!(
            x1.as_slice(),
            x1_ref.as_slice(),
            "session solve_many (λ=1e-2) at {threads} threads differs from serial"
        );
        assert_eq!(
            x2.as_slice(),
            x2_ref.as_slice(),
            "session resweep solve_many (λ=1e-3) at {threads} threads differs from serial"
        );
    }
}
