//! PR-6 mixed-precision integration tests: the `solver.precision =
//! mixed` sessions (f32 Gram/factor/triangular solves + f64 iterative
//! refinement) against the pure-f64 path, the fallback latch on inputs
//! the f32 pipeline cannot represent, and the config-level rejection of
//! the mode on kinds without a mixed session.
//!
//! Refinement convergence contract (see `solver/chol.rs`): each sweep
//! contracts the error by ≈κ(W)·u₃₂ (u₃₂ ≈ 6e-8), so the mixed session
//! converges to `solver.tol` whenever κ(W)·u₃₂ ≪ 1 and otherwise
//! detects stagnation and latches the session back to f64 — observable
//! through `solver::mixed_counters`, never through a wrong answer.

use dngd::config::Config;
use dngd::data::rng::Rng;
use dngd::linalg::{mat::norm2, Mat};
use dngd::solver::{
    mixed_counters, residual_norm, CholSolver, DampedSolver, Precision, RvbSolver, SolverOptions,
};

const TOL: f64 = 1e-10;

fn mixed_chol() -> CholSolver {
    CholSolver::default().with_precision(Precision::Mixed, TOL)
}

/// Well-conditioned problems: the mixed session must hit the refinement
/// target without a single fallback, and its answers must sit at the
/// f64 session's answers to the paper-tolerance bar.
#[test]
fn mixed_session_meets_refinement_target_without_fallbacks() {
    let mut rng = Rng::seed_from(600);
    let fb0 = mixed_counters::fallbacks();
    let mf0 = mixed_counters::mixed_factors();
    for &(n, m, lambda) in &[(8usize, 40usize, 0.5f64), (32, 200, 1e-2), (64, 500, 3e-3)] {
        let s = Mat::randn(n, m, &mut rng);
        let v: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
        let x = mixed_chol().solve(&s, &v, lambda).unwrap();
        // The refinement loop's own contract: true residual ≤ tol·‖v‖.
        let r = residual_norm(&s, &x, &v, lambda);
        assert!(r <= TOL * norm2(&v), "({n},{m},λ={lambda}): residual {r:.3e}");
        // And the answer agrees with the f64 session.
        let x64 = CholSolver::default().solve(&s, &v, lambda).unwrap();
        let scale = norm2(&x64).max(1.0);
        for (a, b) in x.iter().zip(&x64) {
            assert!((a - b).abs() < 1e-8 * scale, "({n},{m}): {a} vs {b}");
        }
    }
    assert_eq!(mixed_counters::fallbacks(), fb0, "no fallback on benign inputs");
    assert!(mixed_counters::mixed_factors() >= mf0 + 3, "every shape used the f32 factor");
}

/// An ill-conditioned Gram (geometric row scaling, norms spread 1e1.5
/// ⇒ Gram eigenvalue spread ~1e3) slows the per-sweep contraction to
/// ~4e-2 (numpy oracle, `python/oracle_precision.py`: 4–5 sweeps over
/// 30 seeds, none stagnant), so reaching 1e-10 provably needs more
/// than one correction sweep — and the sweep counter shows them.
#[test]
fn ill_conditioned_gram_needs_multiple_refinement_sweeps() {
    let mut rng = Rng::seed_from(601);
    let (n, m) = (24usize, 200usize);
    let mut s = Mat::randn(n, m, &mut rng);
    for i in 0..n {
        let scale = 10f64.powf(1.5 * i as f64 / (n - 1) as f64);
        for x in s.row_mut(i) {
            *x *= scale;
        }
    }
    let v: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
    let lambda = 1.0;
    let fb0 = mixed_counters::fallbacks();
    let sw0 = mixed_counters::refine_sweeps();
    let x = mixed_chol().solve(&s, &v, lambda).unwrap();
    assert_eq!(mixed_counters::fallbacks(), fb0, "contraction ≪ 0.7: must converge, not latch");
    let sweeps = mixed_counters::refine_sweeps() - sw0;
    assert!(sweeps >= 2, "this κ cannot reach 1e-10 in one sweep (got {sweeps})");
    assert!(residual_norm(&s, &x, &v, lambda) <= TOL * norm2(&v));
}

/// Scores whose Gram overflows f32 (or degenerates to subnormal) must
/// latch the session to f64 — observable via the fallback counter — and
/// then produce *exactly* the pure-f64 session's answer (after the
/// latch the code path is identical).
#[test]
fn f32_overflow_and_subnormal_gram_fall_back_to_f64() {
    let mut rng = Rng::seed_from(602);
    let (n, m, lambda) = (10usize, 60usize, 0.5f64);
    let base = Mat::randn(n, m, &mut rng);
    let v: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
    // 1e30 is f32-representable but its Gram diagonal (~m·1e60) is not;
    // 1e-30 drives the diagonal subnormal; 1e39 overflows the cast
    // itself. All three must latch.
    for &scale in &[1e30f64, 1e-30, 1e39] {
        let mut s = base.clone();
        for x in s.as_mut_slice() {
            *x *= scale;
        }
        // λ on the data's own scale so the damped f64 system stays sane.
        let l = lambda * scale * scale;
        let fb0 = mixed_counters::fallbacks();
        let mf0 = mixed_counters::mixed_factors();
        let x = mixed_chol().solve(&s, &v, l).unwrap();
        assert!(
            mixed_counters::fallbacks() > fb0,
            "scale {scale:e}: the f32 screen must record a fallback"
        );
        assert_eq!(
            mixed_counters::mixed_factors(),
            mf0,
            "scale {scale:e}: no f32 factor may complete"
        );
        let x64 = CholSolver::default().solve(&s, &v, l).unwrap();
        for (a, b) in x.iter().zip(&x64) {
            assert_eq!(a.to_bits(), b.to_bits(), "latched session must equal the f64 path");
        }
    }
}

/// The mixed session composes with the PR-2 session API: λ-resweeps
/// refactor in f32, and the blocked multi-RHS path refines every row to
/// the target.
#[test]
fn mixed_session_resweeps_and_multi_rhs() {
    let mut rng = Rng::seed_from(603);
    let (n, m, k) = (20usize, 150usize, 6usize);
    let s = Mat::randn(n, m, &mut rng);
    let vs = Mat::randn(k, m, &mut rng);
    let solver = mixed_chol();
    let fb0 = mixed_counters::fallbacks();
    let mut fact = solver.begin(&s);
    for &lambda in &[0.5f64, 1e-2] {
        fact.redamp(lambda).unwrap();
        let x = fact.solve_many(&vs).unwrap();
        for r in 0..k {
            let res = residual_norm(&s, x.row(r), vs.row(r), lambda);
            assert!(res <= TOL * norm2(vs.row(r)), "λ={lambda} rhs {r}: {res:.3e}");
        }
    }
    assert_eq!(mixed_counters::fallbacks(), fb0);
}

/// Streaming rotation has no f32 incremental update: `update_rows` on a
/// mixed session latches it to f64 (counted as a fallback) and the
/// rotated session keeps answering correctly.
#[test]
fn update_rows_latches_mixed_session_to_f64() {
    let mut rng = Rng::seed_from(604);
    let (n, m, lambda) = (12usize, 80usize, 0.1f64);
    let s = Mat::randn(n, m, &mut rng);
    let solver = mixed_chol();
    let mut fact = solver.begin_window(s.clone()).expect("chol owned-window session");
    fact.redamp(lambda).unwrap();
    let added = Mat::randn(2, m, &mut rng);
    let fb0 = mixed_counters::fallbacks();
    fact.update_rows(&[0, 3], &added).unwrap();
    assert!(mixed_counters::fallbacks() > fb0, "rotation must latch the f32 factor");
    // Rotated window: rows {1,2,4..n} then the two added rows.
    let kept: Vec<usize> = (0..n).filter(|&i| i != 0 && i != 3).collect();
    let mut rotated = Mat::zeros(n, m);
    for (i, &oi) in kept.iter().enumerate() {
        rotated.row_mut(i).copy_from_slice(s.row(oi));
    }
    for j in 0..2 {
        rotated.row_mut(n - 2 + j).copy_from_slice(added.row(j));
    }
    let v: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
    let warm = fact.solve(&v).unwrap();
    let cold = CholSolver::default().solve(&rotated, &v, lambda).unwrap();
    let scale = norm2(&cold).max(1.0);
    for (a, b) in warm.iter().zip(&cold) {
        assert!((a - b).abs() < 1e-9 * scale);
    }
}

/// rvb's mixed mode: the recovery stage stays f64, the damped inner
/// solve runs f32 + refinement, and the rowspace precondition still
/// holds. The outer residual bound is ‖S‖·tol·‖f‖ (x = Sᵀu amplifies
/// the refined inner residual by at most ‖S‖).
#[test]
fn rvb_mixed_session_matches_f64() {
    let mut rng = Rng::seed_from(605);
    let (n, m, lambda) = (14usize, 100usize, 0.05f64);
    let s = Mat::randn(n, m, &mut rng);
    let f: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let v = s.t_matvec(&f);
    let fb0 = mixed_counters::fallbacks();
    let mf0 = mixed_counters::mixed_factors();
    let solver = RvbSolver::default().with_precision(Precision::Mixed, TOL);
    let x = solver.solve(&s, &v, lambda).unwrap();
    assert_eq!(mixed_counters::fallbacks(), fb0);
    assert!(mixed_counters::mixed_factors() > mf0, "rvb must use the f32 damped factor");
    let r = residual_norm(&s, &x, &v, lambda);
    assert!(r <= 10.0 * s.fro_norm() * TOL * norm2(&f), "outer residual {r:.3e}");
    let x64 = RvbSolver::default().solve(&s, &v, lambda).unwrap();
    let scale = norm2(&x64).max(1.0);
    for (a, b) in x.iter().zip(&x64) {
        assert!((a - b).abs() < 1e-8 * scale, "{a} vs {b}");
    }
    // Random v with m ≫ n is not Sᵀf: the precondition still rejects.
    let bad: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
    assert!(solver.solve(&s, &bad, lambda).is_err());
}

/// `solver.precision = mixed` is a session feature of chol/rvb only:
/// every other kind rejects it at validation time — option layer and
/// config layer — with an error naming the setting, the offending kind,
/// and the kinds that do support it.
#[test]
fn precision_mixed_rejected_for_unsupported_kinds() {
    let mut opts = SolverOptions::default();
    opts.apply("precision", "mixed").unwrap();
    for (kind_str, kind) in [
        ("eigh", dngd::solver::SolverKind::Eigh),
        ("svda", dngd::solver::SolverKind::Svda),
        ("naive", dngd::solver::SolverKind::Naive),
        ("cg", dngd::solver::SolverKind::Cg),
    ] {
        let err = opts.validate_for(kind).unwrap_err();
        assert!(err.contains("precision=mixed"), "{err}");
        assert!(err.contains(kind_str), "error must name the kind: {err}");
        assert!(err.contains("chol") && err.contains("rvb"), "{err}");
        let cfg_err = Config::from_toml_str(
            &format!("[solver]\nkind = \"{kind_str}\"\nprecision = \"mixed\"\n"),
            &[],
        )
        .unwrap_err();
        assert!(cfg_err.contains("precision=mixed"), "{cfg_err}");
    }
    // Unknown modes fail at parse, naming the known set.
    let err = opts.apply("precision", "bf16").unwrap_err();
    assert!(err.contains("f64") && err.contains("mixed"), "{err}");
}
