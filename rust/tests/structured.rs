//! Integration tests for the PR-10 structured-Fisher solver family:
//! block-diagonal sessions composing the chol/rvb machinery per block,
//! the Kronecker-SVD (K-FAC flavoured) approximate kind, and the
//! structured-preconditioned CG hybrid.
//!
//! The two acceptance bars from the issue are pinned here:
//! * single-block `blockdiag` is **bit-identical** to the plain chol
//!   session — factor, λ-resweep, `solve_many` panels, and streaming
//!   rotation, at 1 and 8 threads;
//! * hybrid PCG takes **strictly fewer** iterations than plain CG on a
//!   blocked synthetic Fisher (≥ 4 blocks).

use dngd::data::rng::Rng;
use dngd::linalg::{KernelConfig, Mat};
use dngd::solver::{
    residual_norm, BlockDiagSolver, BlockKind, BlockPartition, CgSolver, CholSolver,
    DampedSolver, HybridCgSolver, KpSvdSolver, Precision, SolveError, SolverKind,
    SolverOptions, SolverRegistry,
};

/// Synthetic Fisher with real block structure: each block's rows touch
/// only that block's columns, with per-block score scales spread over
/// ~10^1.5 so the Gram's live spectrum spans ~10³ — wide enough that a
/// block preconditioner pays, yet capped so the shared CG/PCG tolerance
/// stays above f64's attainable-residual floor (~ε·κ·‖v‖).
fn blocked_scores(n_per: usize, blocks: usize, width: usize, rng: &mut Rng) -> Mat {
    let mut s = Mat::zeros(n_per * blocks, width * blocks);
    let denom = (blocks.max(2) - 1) as f64;
    for b in 0..blocks {
        let scale = 10f64.powf(1.5 * b as f64 / denom);
        for i in 0..n_per {
            for j in 0..width {
                s[(b * n_per + i, b * width + j)] = scale * rng.normal();
            }
        }
    }
    s
}

#[test]
fn single_block_blockdiag_is_bit_identical_to_chol() {
    let mut rng = Rng::seed_from(1300);
    let (n, m, k_rhs) = (10usize, 32usize, 3usize);
    let s = Mat::randn(n, m, &mut rng);
    let vs = Mat::randn(k_rhs, m, &mut rng);
    for &threads in &[1usize, 8] {
        let cfg = KernelConfig::with_threads(threads);
        let mut chol = CholSolver::with_config(cfg)
            .begin_window(s.clone())
            .expect("chol owned-window session");
        let mut bd = BlockDiagSolver::with_config(cfg)
            .with_blocks(1, BlockKind::Chol)
            .begin_window(s.clone())
            .expect("blockdiag owned-window session");
        // Factor + λ-resweep on the cached Gram: same bits at every λ.
        for &lambda in &[0.5, 1e-2, 1e-4] {
            chol.redamp(lambda).unwrap();
            bd.redamp(lambda).unwrap();
            let xa = chol.solve_many(&vs).unwrap();
            let xb = bd.solve_many(&vs).unwrap();
            for r in 0..k_rhs {
                for (a, b) in xa.row(r).iter().zip(xb.row(r)) {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "threads={threads} λ={lambda}: {a} vs {b}"
                    );
                }
            }
        }
        // Streaming rotation: remove two rows, append two fresh ones.
        let added = Mat::randn(2, m, &mut rng);
        chol.update_rows(&[0, n - 1], &added).unwrap();
        bd.update_rows(&[0, n - 1], &added).unwrap();
        chol.redamp(3e-3).unwrap();
        bd.redamp(3e-3).unwrap();
        let v: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
        let xa = chol.solve(&v).unwrap();
        let xb = bd.solve(&v).unwrap();
        for (a, b) in xa.iter().zip(&xb) {
            assert_eq!(a.to_bits(), b.to_bits(), "post-rotation threads={threads}");
        }
    }
}

#[test]
fn k_block_session_matches_k_independent_chol_solves() {
    let mut rng = Rng::seed_from(1301);
    let (n, m, k) = (9usize, 30usize, 3usize);
    let s = Mat::randn(n, m, &mut rng);
    let v: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
    let lambda = 0.07;
    let solver = BlockDiagSolver::default().with_blocks(k, BlockKind::Chol);
    let x = solver.solve(&s, &v, lambda).unwrap();
    let part = BlockPartition::uniform(m, k).unwrap();
    for &(c0, c1) in part.ranges() {
        let sb = s.slice_cols(c0, c1);
        let xb = CholSolver::default().solve(&sb, &v[c0..c1], lambda).unwrap();
        for (a, b) in x[c0..c1].iter().zip(&xb) {
            assert!((a - b).abs() < 1e-12, "block [{c0},{c1}): {a} vs {b}");
        }
    }
    // Non-uniform explicit partitions route the same way.
    let part = BlockPartition::new(vec![(0, 4), (4, 19), (19, 30)], m).unwrap();
    let x = BlockDiagSolver::default()
        .with_partition(part.clone())
        .with_blocks(0, BlockKind::Chol)
        .solve(&s, &v, lambda)
        .unwrap();
    for &(c0, c1) in part.ranges() {
        let sb = s.slice_cols(c0, c1);
        let xb = CholSolver::default().solve(&sb, &v[c0..c1], lambda).unwrap();
        for (a, b) in x[c0..c1].iter().zip(&xb) {
            assert!((a - b).abs() < 1e-12);
        }
    }
}

#[test]
fn hybrid_pcg_beats_plain_cg_on_blocked_fisher() {
    let mut rng = Rng::seed_from(1302);
    let blocks = 4usize;
    let s = blocked_scores(4, blocks, 8, &mut rng);
    let m = s.cols();
    let v: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
    let lambda = 1e-3;

    // Shared tol 1e-7: above the f64 attainable-residual floor for this
    // κ (so both solvers genuinely converge) while still forcing plain
    // CG through the full spread of the live spectrum.
    let cg = CgSolver::new(1e-7, 10_000);
    let x_cg = cg.solve(&s, &v, lambda).unwrap();
    let cg_iters = cg.stats().iterations;

    let hybrid = HybridCgSolver::new(1e-7, 10_000).with_blocks(blocks, BlockKind::Auto);
    let x_h = hybrid.solve(&s, &v, lambda).unwrap();
    let pcg_iters = hybrid.stats().iterations;

    assert!(
        pcg_iters < cg_iters,
        "structured preconditioning must cut iterations: pcg {pcg_iters} vs cg {cg_iters}"
    );
    // Both answer the *exact* damped system, whatever the iteration gap.
    let vnorm: f64 = v.iter().map(|a| a * a).sum::<f64>().sqrt();
    assert!(residual_norm(&s, &x_cg, &v, lambda) / vnorm < 1e-5);
    assert!(residual_norm(&s, &x_h, &v, lambda) / vnorm < 1e-5);
    // And agree with the direct solver.
    let x_ref = CholSolver::default().solve(&s, &v, lambda).unwrap();
    let scale = x_ref.iter().map(|a| a.abs()).fold(1.0f64, f64::max);
    for (a, b) in x_h.iter().zip(&x_ref) {
        assert!((a - b).abs() < 1e-5 * scale, "{a} vs {b}");
    }
}

#[test]
fn kpsvd_is_exact_when_the_gram_is_a_kronecker_product() {
    // S = A ⊗ B ⟹ SᵀS = (AᵀA) ⊗ (BᵀB): the nearest-Kronecker
    // factorization recovers the Gram exactly, so the damped solve
    // matches chol to solver precision.
    let mut rng = Rng::seed_from(1303);
    let a = Mat::randn(3, 4, &mut rng);
    let b = Mat::randn(4, 5, &mut rng);
    let mut s = Mat::zeros(a.rows() * b.rows(), a.cols() * b.cols());
    for i in 0..a.rows() {
        for j in 0..a.cols() {
            for k in 0..b.rows() {
                for l in 0..b.cols() {
                    s[(i * b.rows() + k, j * b.cols() + l)] = a[(i, j)] * b[(k, l)];
                }
            }
        }
    }
    let m = s.cols();
    let v: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
    for &lambda in &[1.0, 1e-2] {
        let x = KpSvdSolver::default().solve(&s, &v, lambda).unwrap();
        let x_ref = CholSolver::default().solve(&s, &v, lambda).unwrap();
        for (p, q) in x.iter().zip(&x_ref) {
            assert!((p - q).abs() < 1e-8, "λ={lambda}: {p} vs {q}");
        }
    }
}

#[test]
fn degenerate_partitions_are_hard_errors_and_poison_registry_sessions() {
    // Typed BadInput from the partition layer (the seed kfac helper used
    // to stringify these or silently clamp).
    assert!(matches!(BlockPartition::uniform(0, 1), Err(SolveError::BadInput(_))));
    assert!(matches!(BlockPartition::uniform(8, 0), Err(SolveError::BadInput(_))));
    assert!(matches!(BlockPartition::uniform(4, 9), Err(SolveError::BadInput(_))));
    assert!(matches!(
        BlockPartition::new(vec![(0, 3), (4, 8)], 8),
        Err(SolveError::BadInput(_))
    ));
    // `begin` can't fail by contract, so an unusable configuration
    // poisons the session: the stored error surfaces on first use.
    let mut rng = Rng::seed_from(1304);
    let s = Mat::randn(4, 6, &mut rng);
    let bad = BlockDiagSolver::default()
        .with_partition(BlockPartition::uniform(8, 2).unwrap()); // m mismatch
    let mut fact = bad.begin(&s);
    assert!(matches!(fact.redamp(0.1), Err(SolveError::BadInput(_))));
}

#[test]
fn per_kind_option_validation_and_registry_overrides() {
    // Mixed precision composes through the per-block inner sessions of
    // blockdiag and hybrid…
    let mut opts = SolverOptions::default();
    opts.precision = Precision::Mixed;
    opts.validate_for(SolverKind::BlockDiag).unwrap();
    opts.validate_for(SolverKind::Hybrid).unwrap();
    // …and is a named hard error for the eigendecomposition kind.
    let err = opts.validate_for(SolverKind::KpSvd).unwrap_err();
    assert!(err.contains("kpsvd"), "{err}");

    // Mixed-mode blockdiag actually solves, agreeing with f64 chol to
    // the refinement tolerance.
    let mut rng = Rng::seed_from(1305);
    let s = Mat::randn(8, 24, &mut rng);
    let v: Vec<f64> = (0..24).map(|_| rng.normal()).collect();
    let x = BlockDiagSolver::default()
        .with_blocks(3, BlockKind::Chol)
        .with_precision(Precision::Mixed, 1e-10)
        .solve(&s, &v, 0.05)
        .unwrap();
    let solver = BlockDiagSolver::default().with_blocks(3, BlockKind::Chol);
    let x_ref = solver.solve(&s, &v, 0.05).unwrap();
    for (a, b) in x.iter().zip(&x_ref) {
        assert!((a - b).abs() < 1e-7, "{a} vs {b}");
    }

    // `--set solver.*` overrides reach the structured kinds through the
    // registry, and misspelled keys stay hard errors.
    let registry = SolverRegistry::from_overrides(&[
        "solver.blocks=4".to_string(),
        "solver.block_kind=chol".to_string(),
        "solver.hybrid_tol=1e-9".to_string(),
    ])
    .unwrap();
    assert_eq!(registry.opts.blocks, 4);
    assert_eq!(registry.opts.block_kind, BlockKind::Chol);
    assert_eq!(registry.opts.hybrid_tol, 1e-9);
    for kind in [SolverKind::BlockDiag, SolverKind::KpSvd, SolverKind::Hybrid] {
        let solver = registry.build(kind);
        let x = solver.solve(&s, &v, 0.05).unwrap();
        assert_eq!(x.len(), 24, "{kind:?}");
    }
    assert!(SolverRegistry::from_overrides(&["solver.block=4".to_string()]).is_err());
    assert!(SolverRegistry::from_overrides(&["solver.block_kind=kfac".to_string()]).is_err());
}

#[test]
fn structured_bench_strict_mode_holds_the_acceptance_bar() {
    // The same assertions `cargo bench` enforces in full mode, at quick
    // scale: single-block blockdiag ≡ chol to the bit, and PCG strictly
    // under CG on every multi-block row of BENCH_PR10.json.
    dngd::bench_tables::structured_bench_report(true, None, true).unwrap();
}
