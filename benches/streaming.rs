//! PR-5 streaming (sliding-window) bench (EXPERIMENTS.md §Streaming):
//! per-step cost of rotating k of the window's n score rows through a
//! chol owned-window session — `update_rows` (Gram patch + O(kn²)
//! factor rotation) + same-λ `redamp` + solve — against the cold
//! factor path (fresh Gram SYRK + Cholesky + solve) every consumer
//! paid before, with a reconstruct-the-window correctness gate pinning
//! the rotated session to a cold factor at 1e-9.
//!
//! Emits the machine-readable `BENCH_PR5.json` trajectory file (path
//! overridable via `DNGD_BENCH_JSON`; `DNGD_BENCH_QUICK=1` shrinks the
//! shape for CI smoke runs). In full mode the harness *asserts* the
//! PR-5 acceptance bar: rotating ≤10% of a 512-row window is ≥5×
//! faster end-to-end than the cold path (quick mode skips it — tiny
//! shapes under-amortize fixed overheads — but runs the correctness
//! gate in every mode).
//!
//! ```text
//! cargo bench --bench streaming
//! ```

use std::path::Path;

fn main() {
    let quick = std::env::var("DNGD_BENCH_QUICK").map(|v| v != "0").unwrap_or(false);
    let json = std::env::var("DNGD_BENCH_JSON").unwrap_or_else(|_| "BENCH_PR5.json".to_string());
    dngd::bench_tables::streaming_bench_report(quick, Some(Path::new(&json)), !quick)
        .expect("write streaming bench json");
}
