//! Kernel-level microbenches for the perf pass (EXPERIMENTS.md §Perf):
//! SYRK (the O(n²m) Gram stage), blocked Cholesky, triangular solves and
//! the two streaming matvecs, each with achieved-GFLOP/s so roofline
//! headroom is visible per kernel.
//!
//! ```text
//! cargo bench --bench gemm
//! ```

use dngd::data::rng::Rng;
use dngd::linalg::gemm::{syrk, syrk_parallel};
use dngd::linalg::{cholesky, solve_lower, solve_lower_transpose, Mat};
use dngd::metrics::bench;

fn gflops(flops: f64, secs: f64) -> f64 {
    flops / secs / 1e9
}

fn main() {
    let mut rng = Rng::seed_from(9);
    println!("{:>28} | {:>10} | {:>10}", "kernel", "median", "GFLOP/s");

    for &(n, m) in &[(256usize, 8192usize), (512, 8192)] {
        let s = Mat::randn(n, m, &mut rng);
        let r = bench(&format!("syrk {n}x{m}"), 3, 2.0, || {
            std::hint::black_box(syrk(&s, 1e-3));
        });
        let fl = n as f64 * n as f64 * m as f64; // n²m MACs ≈ n²m FLOPs (symmetric half ×2 ops)
        println!(
            "{:>28} | {:>8.2}ms | {:>10.2}",
            format!("syrk {n}×{m}"),
            r.median_ms(),
            gflops(fl, r.summary.median)
        );

        for threads in [2usize, 4, 8] {
            let r = bench(&format!("syrk-par{threads}"), 3, 2.0, || {
                std::hint::black_box(syrk_parallel(&s, 1e-3, threads));
            });
            println!(
                "{:>28} | {:>8.2}ms | {:>10.2}",
                format!("syrk {n}×{m} ({threads} thr)"),
                r.median_ms(),
                gflops(fl, r.summary.median)
            );
        }
    }

    for &n in &[256usize, 512, 1024] {
        let a = Mat::randn(n, n + 8, &mut rng);
        let w = syrk(&a, 1.0);
        let r = bench(&format!("chol {n}"), 3, 2.0, || {
            std::hint::black_box(cholesky(&w).unwrap());
        });
        let fl = (n as f64).powi(3) / 3.0;
        println!(
            "{:>28} | {:>8.2}ms | {:>10.2}",
            format!("cholesky {n}×{n}"),
            r.median_ms(),
            gflops(fl, r.summary.median)
        );

        let l = cholesky(&w).unwrap();
        let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let r = bench(&format!("trisolve {n}"), 5, 1.0, || {
            let y = solve_lower(&l, &b);
            std::hint::black_box(solve_lower_transpose(&l, &y));
        });
        let fl = 2.0 * (n as f64) * (n as f64);
        println!(
            "{:>28} | {:>8.3}ms | {:>10.2}",
            format!("trisolve fwd+adj {n}"),
            r.median_ms(),
            gflops(fl, r.summary.median)
        );
    }

    // Streaming matvecs (memory-bound): report effective GB/s too.
    let (n, m) = (512usize, 65536usize);
    let s = Mat::randn(n, m, &mut rng);
    let v: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
    let z: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let bytes = (n * m * 8) as f64;
    let r = bench("matvec", 5, 1.0, || {
        std::hint::black_box(s.matvec(&v));
    });
    println!(
        "{:>28} | {:>8.2}ms | {:>7.1} GB/s",
        format!("S·v {n}×{m}"),
        r.median_ms(),
        bytes / r.summary.median / 1e9
    );
    let r = bench("tmatvec", 5, 1.0, || {
        std::hint::black_box(s.t_matvec(&z));
    });
    println!(
        "{:>28} | {:>8.2}ms | {:>7.1} GB/s",
        format!("Sᵀ·z {n}×{m}"),
        r.median_ms(),
        bytes / r.summary.median / 1e9
    );
}
