//! Kernel-level microbenches for the perf pass (EXPERIMENTS.md §Perf,
//! §SIMD): the packed-engine SYRK / GEMM / Cholesky / blocked TRSM
//! against the seed scalar kernels, plus the end-to-end Algorithm-1
//! solve, each with achieved GFLOP/s so roofline headroom is visible
//! per kernel — followed by the PR-4 ISA-tier sweep (scalar tier vs
//! best dispatched tier, single-threaded).
//!
//! Emits the machine-readable `BENCH_PR1.json` trajectory file (path
//! overridable via `DNGD_BENCH_JSON`) and `BENCH_PR4.json`
//! (`DNGD_BENCH_JSON_SIMD`); `DNGD_BENCH_QUICK=1` shrinks every shape
//! for CI smoke runs and skips the PR-4 acceptance assert (best tier
//! ≥ 2× scalar on 512³ single-threaded DGEMM), which full mode
//! enforces.
//!
//! ```text
//! cargo bench --bench gemm
//! ```

use dngd::data::rng::Rng;
use dngd::linalg::Mat;
use dngd::metrics::bench;
use std::path::Path;

fn main() {
    let quick = std::env::var("DNGD_BENCH_QUICK").map(|v| v != "0").unwrap_or(false);
    let json = std::env::var("DNGD_BENCH_JSON").unwrap_or_else(|_| "BENCH_PR1.json".to_string());
    dngd::bench_tables::kernel_bench_report(quick, Some(Path::new(&json)))
        .expect("write bench json");

    // PR-4 ISA-tier sweep + acceptance (strict in full mode only).
    let json4 = std::env::var("DNGD_BENCH_JSON_SIMD")
        .unwrap_or_else(|_| "BENCH_PR4.json".to_string());
    dngd::bench_tables::simd_bench_report(quick, Some(Path::new(&json4)), !quick)
        .expect("write simd bench json");

    // PR-6 mixed-precision sweep + acceptance (f32 GEMM/SYRK ≥ 1.5×
    // f64 on the best tier; strict in full mode only, and skipped on
    // scalar-only hosts by the report itself).
    let json6 = std::env::var("DNGD_BENCH_JSON_PRECISION")
        .unwrap_or_else(|_| "BENCH_PR6.json".to_string());
    dngd::bench_tables::precision_bench_report(quick, Some(Path::new(&json6)), !quick)
        .expect("write precision bench json");

    // Streaming matvecs (memory-bound): effective GB/s for the O(nm)
    // passes of Algorithm 1 line 4. Not part of the JSON trajectory —
    // these are bandwidth-, not kernel-, limited.
    let (n, m) = if quick { (64usize, 4096usize) } else { (512usize, 65536usize) };
    let mut rng = Rng::seed_from(9);
    let s = Mat::randn(n, m, &mut rng);
    let v: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
    let z: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let bytes = (n * m * 8) as f64;
    let r = bench("matvec", 5, 0.2, || {
        std::hint::black_box(s.matvec(&v));
    });
    println!(
        "{:>22} | {:>8.2}ms | {:>7.1} GB/s",
        format!("S·v {n}×{m}"),
        r.median_ms(),
        bytes / r.summary.median / 1e9
    );
    let r = bench("tmatvec", 5, 0.2, || {
        std::hint::black_box(s.t_matvec(&z));
    });
    println!(
        "{:>22} | {:>8.2}ms | {:>7.1} GB/s",
        format!("Sᵀ·z {n}×{m}"),
        r.median_ms(),
        bytes / r.summary.median / 1e9
    );
}
