//! PR-7/PR-8 serving benches (EXPERIMENTS.md §Serving, §Fault-tolerance):
//!
//! 1. **Serving** — sustained multi-tenant traffic against the
//!    damped-solve server at 1/4/16 concurrent tenants, with coalesced
//!    dispatch (compatible RHS batched into one `solve_many` panel per
//!    tick) measured against the serial per-request baseline. Reports
//!    requests/sec plus client-observed p50/p99 latency, and gates every
//!    answer against the serial `chol` solver at 1e-9. Emits
//!    `BENCH_PR7.json`.
//! 2. **Recovery** — a single-tenant stream with a worker killed every
//!    ~100 requests (~20 in quick mode); the p99 gap vs the fault-free
//!    baseline is the client-visible cost of supervisor respawn +
//!    session re-materialization. Emits `BENCH_PR8.json` (path
//!    overridable via `DNGD_BENCH_JSON_RECOVERY`).
//!
//! `DNGD_BENCH_JSON` overrides the PR-7 path; `DNGD_BENCH_QUICK=1`
//! shrinks the shapes for CI smoke runs. In full mode the harness
//! *asserts* both acceptance bars: coalesced dispatch at 16 tenants
//! sustains ≥2× the requests/sec of serial dispatch without degrading
//! p99, and every injected kill recovers through the distributed
//! replay/refactor paths (zero leader-local fallbacks). Quick mode
//! skips the timing bars but runs every correctness gate.
//!
//! ```text
//! cargo bench --bench serving
//! ```

use std::path::Path;

fn main() {
    let quick = std::env::var("DNGD_BENCH_QUICK").map(|v| v != "0").unwrap_or(false);
    let json = std::env::var("DNGD_BENCH_JSON").unwrap_or_else(|_| "BENCH_PR7.json".to_string());
    dngd::bench_tables::serving_bench_report(quick, Some(Path::new(&json)), !quick)
        .expect("write serving bench json");
    let json8 = std::env::var("DNGD_BENCH_JSON_RECOVERY")
        .unwrap_or_else(|_| "BENCH_PR8.json".to_string());
    dngd::bench_tables::recovery_bench_report(quick, Some(Path::new(&json8)), !quick)
        .expect("write recovery bench json");
}
