//! PR-7 serving bench (EXPERIMENTS.md §Serving): sustained multi-tenant
//! traffic against the damped-solve server at 1/4/16 concurrent tenants,
//! with coalesced dispatch (compatible RHS batched into one `solve_many`
//! panel per tick) measured against the serial per-request baseline.
//! Reports requests/sec plus client-observed p50/p99 latency, and gates
//! every answer against the serial `chol` solver at 1e-9.
//!
//! Emits the machine-readable `BENCH_PR7.json` file (path overridable
//! via `DNGD_BENCH_JSON`; `DNGD_BENCH_QUICK=1` shrinks the shape for CI
//! smoke runs). In full mode the harness *asserts* the PR-7 acceptance
//! bar: coalesced dispatch at 16 tenants sustains ≥2× the requests/sec
//! of serial dispatch without degrading p99 (quick mode skips it — at
//! tiny shapes the dispatch tick dominates the panel GEMM — but runs
//! the correctness gate in every mode).
//!
//! ```text
//! cargo bench --bench serving
//! ```

use std::path::Path;

fn main() {
    let quick = std::env::var("DNGD_BENCH_QUICK").map(|v| v != "0").unwrap_or(false);
    let json = std::env::var("DNGD_BENCH_JSON").unwrap_or_else(|_| "BENCH_PR7.json".to_string());
    dngd::bench_tables::serving_bench_report(quick, Some(Path::new(&json)), !quick)
        .expect("write serving bench json");
}
