//! Regenerates **Table 1** of the paper: wall time of one damped solve
//! for chol / eigh / svda over the ten (n, m) shapes, plus the svda
//! `N/A` memory cell. `DNGD_PAPER_SCALE=1` runs the paper's exact shapes
//! (slow on CPU); default is the proportionally scaled grid. Solves run
//! through the PR-2 session shim (factor → solve_into); the amortized
//! (factor-once) timings live in `cargo bench --bench sessions`.
//!
//! ```text
//! cargo bench --bench table1
//! ```

fn main() {
    let paper = std::env::var("DNGD_PAPER_SCALE").is_ok();
    dngd::bench_tables::table1(paper);
}
