//! Regenerates **Fig. 1 (left panel)**: time vs n at fixed m for all
//! three methods, with the fitted exponent against the paper's dotted
//! ideal O(n²) line. (The harness prints both panels; this bench is the
//! n-sweep entry point, `scaling_m` the m-sweep.)
//!
//! ```text
//! cargo bench --bench scaling_n
//! ```

fn main() {
    let paper = std::env::var("DNGD_PAPER_SCALE").is_ok();
    dngd::bench_tables::scaling(paper);
}
