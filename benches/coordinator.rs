//! Coordinator scaling ablation: the sharded distributed Algorithm 1 vs
//! the serial solver across worker counts, plus the KFAC block-diagonal
//! ablation (DESIGN.md experiment index, extension rows).
//!
//! ```text
//! cargo bench --bench coordinator
//! ```

use dngd::coordinator::ShardedCholSolver;
use dngd::data::rng::Rng;
use dngd::linalg::Mat;
use dngd::metrics::bench;
use dngd::ngd::BlockDiagonalFisher;
use dngd::solver::{CholSolver, DampedSolver};

fn main() {
    let mut rng = Rng::seed_from(31);
    let (n, m) = (256usize, 16384usize);
    let lambda = 1e-3;
    let s = Mat::randn(n, m, &mut rng);
    let v: Vec<f64> = (0..m).map(|_| rng.normal()).collect();

    println!("distributed Algorithm 1, S: {n}×{m}");
    println!("{:>22} | {:>10} | speedup", "configuration", "median");
    let serial = bench("serial", 3, 2.0, || {
        std::hint::black_box(CholSolver::default().solve(&s, &v, lambda).unwrap());
    });
    println!("{:>22} | {:>8.2}ms | 1.00×", "serial chol", serial.median_ms());

    for workers in [2usize, 4, 8] {
        let solver = ShardedCholSolver::new(workers, 2);
        let r = bench(&format!("sharded{workers}"), 3, 2.0, || {
            std::hint::black_box(solver.solve_distributed(&s, &v, lambda).unwrap());
        });
        println!(
            "{:>22} | {:>8.2}ms | {:.2}×",
            format!("sharded ×{workers}"),
            r.median_ms(),
            serial.median_ms() / r.median_ms()
        );
    }

    // KFAC-style block-diagonal ablation: faster, but *approximate* —
    // report both the time and the solution error vs the exact solve.
    println!("\nblock-diagonal (KFAC-family) ablation");
    println!("{:>22} | {:>10} | rel. solution error", "blocks", "median");
    let exact = CholSolver::default().solve(&s, &v, lambda).unwrap();
    let exact_norm = exact.iter().map(|x| x * x).sum::<f64>().sqrt();
    for blocks in [1usize, 4, 16, 64] {
        let bd = BlockDiagonalFisher::uniform(m, blocks);
        let r = bench(&format!("bd{blocks}"), 3, 1.0, || {
            std::hint::black_box(bd.solve(&s, &v, lambda).unwrap());
        });
        let x = bd.solve(&s, &v, lambda).unwrap();
        let err = x
            .iter()
            .zip(&exact)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f64>()
            .sqrt()
            / exact_norm;
        println!("{:>22} | {:>8.2}ms | {err:.3e}", format!("{blocks} block(s)"), r.median_ms());
    }
    println!("\n§1: approximations (KFAC) trade exactness for speed — the error column is the gap\nAlgorithm 1 closes at comparable cost.");
}
