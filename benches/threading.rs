//! PR-3 thread-scaling bench (EXPERIMENTS.md §Threading): SYRK, GEMM,
//! Cholesky, multi-RHS TRSM and the end-to-end chol session
//! (`begin → redamp → 16-RHS solve_many`) swept over 1/2/4/8 pool
//! threads, with every threaded output checked bit-identical to its
//! serial counterpart.
//!
//! Emits the machine-readable `BENCH_PR3.json` trajectory file (path
//! overridable via `DNGD_BENCH_JSON`; `DNGD_BENCH_QUICK=1` shrinks every
//! shape for CI smoke runs). In full mode the harness *asserts* the PR-3
//! acceptance bar: end-to-end session ≥ 3× serial at 8 threads (quick
//! mode skips it — CI boxes have arbitrary core counts — but asserts
//! bit-identity in every mode).
//!
//! ```text
//! cargo bench --bench threading
//! ```

use std::path::Path;

fn main() {
    let quick = std::env::var("DNGD_BENCH_QUICK").map(|v| v != "0").unwrap_or(false);
    let json = std::env::var("DNGD_BENCH_JSON").unwrap_or_else(|_| "BENCH_PR3.json".to_string());
    dngd::bench_tables::thread_bench_report(quick, Some(Path::new(&json)), !quick)
        .expect("write thread bench json");
}
