//! Regenerates the §3 iterative-baseline claim: CG iteration counts blow
//! up as the damped system becomes ill-conditioned (λ ↓), while the
//! direct Cholesky solve stays flat.
//!
//! ```text
//! cargo bench --bench cg_conditioning
//! ```

fn main() {
    dngd::bench_tables::cg_conditioning();
}
