//! Regenerates **Fig. 1 (right panel)**: time vs m at fixed n, fitted
//! exponent against the ideal O(m) line. Shares the harness with
//! `scaling_n` (both panels print together, matching the figure).
//!
//! ```text
//! cargo bench --bench scaling_m
//! ```

fn main() {
    let paper = std::env::var("DNGD_PAPER_SCALE").is_ok();
    dngd::bench_tables::scaling(paper);
}
