//! PR-2 session-API amortization bench (EXPERIMENTS.md §Sessions): k
//! one-shot Algorithm-1 solves vs factor-once + blocked multi-RHS +
//! λ-resweeps on the cached Gram, at the acceptance shapes
//! (n ∈ {256, 1024}, m = 16384, k = 8).
//!
//! Emits the machine-readable `BENCH_PR2.json` trajectory file (path
//! overridable via `DNGD_BENCH_JSON`; `DNGD_BENCH_QUICK=1` shrinks every
//! shape for CI smoke runs). In full mode the harness *asserts* the PR-2
//! acceptance bar: amortized ≥ 3× cold on every row.
//!
//! ```text
//! cargo bench --bench sessions
//! ```

use std::path::Path;

fn main() {
    let quick = std::env::var("DNGD_BENCH_QUICK").map(|v| v != "0").unwrap_or(false);
    let json = std::env::var("DNGD_BENCH_JSON").unwrap_or_else(|_| "BENCH_PR2.json".to_string());
    dngd::bench_tables::session_bench_report(quick, Some(Path::new(&json)), !quick)
        .expect("write session bench json");
}
