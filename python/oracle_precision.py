"""Numerical oracle for the PR-6 mixed-precision refinement (no Rust
toolchain needed): simulates `MixedState` from `rust/src/solver/chol.rs`
bit-for-strategy — f32 score copy, f32 Gram, f64-accumulated damped
diagonal, f32 Cholesky + triangular solves, f64 true-residual
refinement with the same stagnation rule (0.7) and sweep cap (40) —
and reports, per test regime used by the Rust suite, the observed
contraction rate, sweep count, fallback behaviour and final relative
residual across seeds.

Run:  python3 python/oracle_precision.py

The regimes mirror `rust/tests/precision.rs`, the `chol.rs`/`rvb.rs`
unit tests and the `bench_tables::precision_bench` shapes. The RNG is
not the crate's (numpy vs the in-tree xorshift), so the oracle answers
the *statistical* question — does each regime converge with margin? —
not the bitwise one.
"""

import numpy as np
from scipy.linalg import solve_triangular

MAX_SWEEPS = 40
STAGNATION = 0.7


def mixed_solve(s, lam, v, tol=1e-10):
    """Return (x, sweeps, status, final_rel_resid, worst_contraction).

    status: 'converged' | 'stagnated' | 'exhausted' | 'f32-breakdown'.
    """
    n, m = s.shape
    s32 = s.astype(np.float32)
    if not np.isfinite(s32).all():
        return None, 0, "f32-breakdown", np.inf, np.inf
    w32 = s32 @ s32.T  # f32 Gram
    diag = np.einsum("ij,ij->i", s, s)  # f64 diagonal
    a32 = w32.copy()
    a32[np.diag_indices(n)] = (diag + lam).astype(np.float32)
    if not np.isfinite(a32).all() or np.any(
        (diag + lam <= 0) | ((diag + lam).astype(np.float32) < np.float32(1.2e-38))
    ):
        return None, 0, "f32-breakdown", np.inf, np.inf
    try:
        l32 = np.linalg.cholesky(a32)  # spotrf: stays f32
    except np.linalg.LinAlgError:
        return None, 0, "f32-breakdown", np.inf, np.inf
    assert l32.dtype == np.float32

    def apply_inverse(b):
        # (b - S^T L^-T L^-1 S b)/lam with f64 matvecs, f32 solves.
        u = (s @ b).astype(np.float32)
        y = solve_triangular(l32, u, lower=True)
        z = solve_triangular(l32, y, lower=True, trans="T").astype(np.float64)
        return (b - s.T @ z) / lam

    x = apply_inverse(v)
    vnorm = np.linalg.norm(v)
    prev = np.inf
    worst_c = 0.0
    for sweep in range(MAX_SWEEPS):
        r = v - lam * x - s.T @ (s @ x)
        rnorm = np.linalg.norm(r)
        if not np.isfinite(rnorm):
            return x, sweep, "stagnated", rnorm / vnorm, worst_c
        if rnorm <= tol * vnorm:
            return x, sweep, "converged", rnorm / vnorm, worst_c
        if rnorm >= STAGNATION * prev:
            return x, sweep, "stagnated", rnorm / vnorm, worst_c
        if np.isfinite(prev):
            worst_c = max(worst_c, rnorm / prev)
        prev = rnorm
        x = x + apply_inverse(r)
    return x, MAX_SWEEPS, "exhausted", rnorm / vnorm, worst_c


def gram_mixed_solve(g, lam, f, tol=1e-10):
    """rvb inner solve: (G + lam I) u = f, f32 factor + f64 refinement."""
    n = g.shape[0]
    a32 = g.astype(np.float32)
    a32[np.diag_indices(n)] = (np.diag(g) + lam).astype(np.float32)
    l32 = np.linalg.cholesky(a32)
    u = solve_triangular(
        l32, solve_triangular(l32, f.astype(np.float32), lower=True), lower=True, trans="T"
    ).astype(np.float64)
    fnorm = np.linalg.norm(f)
    prev = np.inf
    for sweep in range(MAX_SWEEPS):
        r = f - lam * u - g @ u
        rnorm = np.linalg.norm(r)
        if rnorm <= tol * fnorm:
            return u, sweep, "converged"
        if rnorm >= STAGNATION * prev:
            return u, sweep, "stagnated"
        prev = rnorm
        d = solve_triangular(
            l32, solve_triangular(l32, r.astype(np.float32), lower=True), lower=True, trans="T"
        ).astype(np.float64)
        u = u + d
    return u, MAX_SWEEPS, "exhausted"


def f64_solve(s, lam, v):
    n = s.shape[0]
    a = s @ s.T
    a[np.diag_indices(n)] += lam
    l = np.linalg.cholesky(a)
    z = solve_triangular(l, solve_triangular(l, s @ v, lower=True), lower=True, trans="T")
    return (v - s.T @ z) / lam


def run_regime(name, make, seeds=range(12), tol=1e-10):
    sweeps, status, rel, contr, err64 = [], {}, [], [], []
    for seed in seeds:
        rng = np.random.default_rng(seed)
        s, lam, v = make(rng)
        x, sw, st, rr, c = mixed_solve(s, lam, v, tol)
        sweeps.append(sw)
        status[st] = status.get(st, 0) + 1
        rel.append(rr)
        contr.append(c)
        if st == "converged":
            x64 = f64_solve(s, lam, v)
            err64.append(
                np.linalg.norm(x - x64) / max(np.linalg.norm(x64), 1.0)
            )
    print(
        f"{name:46s} sweeps[{min(sweeps)},{max(sweeps)}] status={status} "
        f"max_contr={max(contr):.2e} max_rel_resid={max(rel):.2e} "
        f"max_err_vs_f64={max(err64) if err64 else float('nan'):.2e}"
    )
    return sweeps, status


def main():
    results = {}

    def randn_regime(n, m, lam):
        return lambda rng: (rng.standard_normal((n, m)), lam, rng.standard_normal(m))

    # precision.rs::mixed_session_meets_refinement_target_without_fallbacks
    for n, m, lam in [(8, 40, 0.5), (32, 200, 1e-2), (64, 500, 3e-3)]:
        results[(n, m, lam)] = run_regime(
            f"well-conditioned n={n} m={m} lam={lam}", randn_regime(n, m, lam)
        )

    # chol.rs unit tests: (24,160) lam in {0.5, 1e-2}; (20,120) lam=0.1
    run_regime("chol.rs unit n=24 m=160 lam=0.5", randn_regime(24, 160, 0.5))
    run_regime("chol.rs unit n=24 m=160 lam=1e-2", randn_regime(24, 160, 1e-2))
    run_regime("chol.rs multi-rhs n=20 m=120 lam=0.1", randn_regime(20, 120, 0.1))

    # precision.rs::ill_conditioned_gram_needs_multiple_refinement_sweeps
    def ill(spread, lam, n=24, m=200):
        def make(rng):
            s = rng.standard_normal((n, m))
            s *= 10.0 ** (spread * np.arange(n) / (n - 1))[:, None]
            return s, lam, rng.standard_normal(m)

        return make

    # The shipped test regime is spread=1e1.5, lam=1.0 (4-5 sweeps, max
    # contraction ~4e-2). The others map the latch boundary: spread
    # 1e2.5 at lam=1 and spread 1e2 at lam=1e-2 stagnate (the fallback
    # path), spread 1e2 at lam>=1 still converges.
    for spread, lam in [(1.5, 1.0), (2.0, 1.0), (2.0, 10.0), (2.0, 1e-2), (2.5, 1.0)]:
        run_regime(f"ill-conditioned spread=1e{spread} lam={lam}", ill(spread, lam))

    # bench_tables::precision_bench shapes (lam=0.1: 3-4 sweeps; at
    # lam=1e-3 the full shape stagnates, hence the bench's choice).
    run_regime("bench quick n=96 m=512 lam=0.1", randn_regime(96, 512, 0.1), seeds=range(4))
    run_regime("bench full n=512 m=4096 lam=0.1", randn_regime(512, 4096, 0.1), seeds=range(2))
    run_regime("bench full lam=1e-3 (stagnates)", randn_regime(512, 4096, 1e-3), seeds=range(2))

    # rvb inner Gram solve regimes (n x n, benign by construction).
    for n, m, lam in [(12, 90, 0.05), (14, 100, 0.05)]:
        st = {}
        sw_all = []
        for seed in range(12):
            rng = np.random.default_rng(seed)
            s = rng.standard_normal((n, m))
            g = s @ s.T
            f = rng.standard_normal(n)
            _, sw, s_ = gram_mixed_solve(g, lam, f)
            st[s_] = st.get(s_, 0) + 1
            sw_all.append(sw)
        print(f"{f'rvb inner n={n} m={m} lam={lam}':46s} sweeps[{min(sw_all)},{max(sw_all)}] status={st}")


if __name__ == "__main__":
    main()
