"""L1 kernel correctness: every Pallas kernel vs its pure-jnp oracle,
swept over shapes and dtypes with hypothesis."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import cholesky as chol_k
from compile.kernels import gram as gram_k
from compile.kernels import matvec as mv_k
from compile.kernels import ref
from compile.kernels import trisolve as tri_k

# Interpret-mode Pallas is slow; keep hypothesis examples modest but
# meaningful.
KERNEL_SETTINGS = settings(max_examples=12, deadline=None)


def rand(shape, seed, dtype=np.float32):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=shape), dtype=dtype)


def spd(n, seed, lam=1.0, dtype=np.float32):
    a = rand((n, n + 3), seed, dtype)
    return a @ a.T + lam * jnp.eye(n, dtype=dtype)


class TestGram:
    @KERNEL_SETTINGS
    @given(
        n=st.integers(1, 40),
        m=st.integers(1, 300),
        lam=st.floats(1e-4, 10.0),
        seed=st.integers(0, 2**31),
    )
    def test_matches_ref_swept(self, n, m, lam, seed):
        s = rand((n, m), seed)
        got = gram_k.gram(s, jnp.float32(lam))
        want = ref.gram_ref(s, jnp.float32(lam))
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4 * m**0.5)

    def test_tile_boundaries(self):
        # Exactly one tile, tile+1, tile-1, multiple tiles.
        for n in [127, 128, 129, 256]:
            for m in [511, 512, 513]:
                s = rand((n, m), n * 1000 + m)
                got = gram_k.gram(s, jnp.float32(0.5))
                want = ref.gram_ref(s, jnp.float32(0.5))
                np.testing.assert_allclose(got, want, rtol=3e-4, atol=2e-2)

    def test_symmetry(self):
        s = rand((33, 200), 7)
        w = gram_k.gram(s, jnp.float32(1e-3))
        np.testing.assert_allclose(w, w.T, rtol=0, atol=1e-5)

    def test_float64(self):
        # interpret mode runs the math in the requested dtype.
        s = rand((9, 50), 3, np.float32)
        w = gram_k.gram(s, jnp.float32(0.0))
        assert w.dtype == s.dtype


class TestMatvec:
    @KERNEL_SETTINGS
    @given(n=st.integers(1, 50), m=st.integers(1, 400), seed=st.integers(0, 2**31))
    def test_matvec_swept(self, n, m, seed):
        s = rand((n, m), seed)
        v = rand((m,), seed + 1)
        np.testing.assert_allclose(
            mv_k.matvec(s, v), ref.matvec_ref(s, v), rtol=2e-4, atol=2e-4 * m**0.5
        )

    @KERNEL_SETTINGS
    @given(n=st.integers(1, 50), m=st.integers(1, 400), seed=st.integers(0, 2**31))
    def test_tmatvec_swept(self, n, m, seed):
        s = rand((n, m), seed)
        z = rand((n,), seed + 2)
        np.testing.assert_allclose(
            mv_k.tmatvec(s, z), ref.tmatvec_ref(s, z), rtol=2e-4, atol=1e-4 * n
        )

    def test_tile_boundaries(self):
        for m in [2047, 2048, 2049]:
            s = rand((130, m), m)
            v = rand((m,), m + 1)
            z = rand((130,), m + 2)
            np.testing.assert_allclose(
                mv_k.matvec(s, v), ref.matvec_ref(s, v), rtol=3e-4, atol=3e-2
            )
            np.testing.assert_allclose(
                mv_k.tmatvec(s, z), ref.tmatvec_ref(s, z), rtol=3e-4, atol=3e-2
            )


class TestCholesky:
    @KERNEL_SETTINGS
    @given(n=st.integers(1, 48), seed=st.integers(0, 2**31))
    def test_reconstruction_swept(self, n, seed):
        w = spd(n, seed)
        l = chol_k.cholesky(w)
        np.testing.assert_allclose(l @ l.T, w, rtol=1e-3, atol=1e-3 * n)
        # Lower-triangular with positive diagonal.
        lnp = np.asarray(l)
        assert np.allclose(np.triu(lnp, 1), 0.0)
        assert (np.diag(lnp) > 0).all()

    def test_matches_jnp_cholesky(self):
        w = spd(20, 11)
        np.testing.assert_allclose(
            chol_k.cholesky(w), ref.cholesky_ref(w), rtol=1e-4, atol=1e-4
        )

    def test_identity(self):
        eye = jnp.eye(7, dtype=jnp.float32)
        np.testing.assert_allclose(chol_k.cholesky(eye), eye, atol=1e-7)


class TestTrisolve:
    @KERNEL_SETTINGS
    @given(n=st.integers(1, 48), seed=st.integers(0, 2**31))
    def test_forward_swept(self, n, seed):
        l = ref.cholesky_ref(spd(n, seed))
        b = rand((n,), seed + 1)
        got = tri_k.solve_lower(l, b)
        want = ref.trisolve_ref(l, b, trans=False)
        np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)

    @KERNEL_SETTINGS
    @given(n=st.integers(1, 48), seed=st.integers(0, 2**31))
    def test_adjoint_swept(self, n, seed):
        l = ref.cholesky_ref(spd(n, seed))
        y = rand((n,), seed + 2)
        got = tri_k.solve_lower_t(l, y)
        want = ref.trisolve_ref(l, y, trans=True)
        np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)

    def test_roundtrip(self):
        l = ref.cholesky_ref(spd(25, 5))
        y = rand((25,), 6)
        b = l @ y
        np.testing.assert_allclose(tri_k.solve_lower(l, b), y, rtol=1e-3, atol=1e-3)


class TestVmemModel:
    def test_gram_vmem_budget(self):
        # Default tiling must fit VMEM (~16 MB) with double buffering.
        assert gram_k.vmem_bytes() < 16 * 2**20
