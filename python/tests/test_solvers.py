"""L2 solver correctness: Algorithm 1 (Pallas composition) and the
baselines vs the dense m×m oracle, plus cross-method agreement — the
executable version of the paper's Appendix A."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import solvers
from compile.kernels import ref

SOLVER_SETTINGS = settings(max_examples=10, deadline=None)


def problem(n, m, seed, dtype=np.float32):
    rng = np.random.default_rng(seed)
    s = jnp.asarray(rng.normal(size=(n, m)), dtype=dtype)
    v = jnp.asarray(rng.normal(size=(m,)), dtype=dtype)
    return s, v


def residual(s, x, v, lam):
    return float(jnp.linalg.norm(s.T @ (s @ x) + lam * x - v))


class TestAlgorithm1:
    @SOLVER_SETTINGS
    @given(
        n=st.integers(1, 24),
        extra=st.integers(0, 80),
        lam=st.floats(1e-3, 10.0),
        seed=st.integers(0, 2**31),
    )
    def test_pallas_solve_vs_dense_oracle(self, n, extra, lam, seed):
        m = n + extra
        s, v = problem(n, m, seed)
        x = solvers.damped_solve(s, v, jnp.float32(lam))
        want = ref.damped_solve_dense_oracle(s, v, jnp.float32(lam))
        scale = float(jnp.max(jnp.abs(want))) + 1.0
        np.testing.assert_allclose(x, want, rtol=0, atol=3e-3 * scale)

    def test_residual_small(self):
        s, v = problem(16, 200, 1)
        lam = jnp.float32(0.05)
        x = solvers.damped_solve(s, v, lam)
        assert residual(s, x, v, lam) < 1e-2 * float(jnp.linalg.norm(x))

    def test_pallas_equals_jnp_path(self):
        s, v = problem(12, 90, 2)
        lam = jnp.float32(0.1)
        a = solvers.damped_solve(s, v, lam)
        b = solvers.damped_solve_jnp(s, v, lam)
        np.testing.assert_allclose(a, b, rtol=0, atol=1e-3 * (1.0 + float(jnp.max(jnp.abs(b)))))


class TestBaselines:
    @SOLVER_SETTINGS
    @given(n=st.integers(2, 20), extra=st.integers(0, 60), seed=st.integers(0, 2**31))
    def test_eigh_and_svd_agree_with_chol(self, n, extra, seed):
        m = n + extra
        s, v = problem(n, m, seed)
        lam = jnp.float32(0.2)
        want = ref.damped_solve_dense_oracle(s, v, lam)
        scale = float(jnp.max(jnp.abs(want))) + 1.0
        for fn in (solvers.eigh_solve, solvers.svd_solve):
            got = fn(s, v, lam)
            np.testing.assert_allclose(got, want, rtol=0, atol=5e-3 * scale)

    def test_cg_converges_and_counts_iterations(self):
        s, v = problem(10, 80, 3)
        lam = jnp.float32(1.0)
        x, iters = solvers.cg_solve(s, v, lam)
        want = ref.damped_solve_dense_oracle(s, v, lam)
        np.testing.assert_allclose(x, want, rtol=0, atol=1e-3)
        assert 0 < int(iters) < 200

    def test_cg_iterations_grow_when_ill_conditioned(self):
        # §3: iterative methods degrade with conditioning; direct chol
        # does not. Scale rows geometrically, shrink λ.
        rng = np.random.default_rng(4)
        n, m = 16, 120
        s = rng.normal(size=(n, m))
        s *= np.logspace(0, 2, n)[:, None]
        s = jnp.asarray(s, dtype=jnp.float32)
        v = jnp.asarray(rng.normal(size=(m,)), dtype=jnp.float32)
        _, it_well = solvers.cg_solve(s, v, jnp.float32(1e1), tol=1e-6)
        _, it_ill = solvers.cg_solve(s, v, jnp.float32(1e-3), tol=1e-6)
        assert int(it_ill) > 2 * int(it_well)


class TestRankDeficiency:
    def test_duplicate_rows_need_damping(self):
        s, v = problem(6, 40, 5)
        s = s.at[5].set(s[0])  # rank-deficient Gram
        lam = jnp.float32(1e-2)
        x = solvers.damped_solve(s, v, lam)
        want = ref.damped_solve_dense_oracle(s, v, lam)
        np.testing.assert_allclose(x, want, rtol=0, atol=5e-3 * (1 + float(jnp.max(jnp.abs(want)))))
