"""L2 model: vmap'd per-sample scores vs explicit loops, NGD-step descent,
and the score/gradient linear relation the paper's framing relies on."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model, solvers


def setup(n=12, d=4, k=3, seed=0):
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    params = model.init_mlp([d, 8, k], k1)
    xs = jax.random.normal(k2, (n, d))
    ys = jax.random.randint(k3, (n,), 0, k)
    return params, xs, ys


class TestScores:
    def test_score_rows_match_per_sample_grad_loop(self):
        params, xs, ys = setup()
        s = model.score_matrix(params, xs, ys)
        flat, treedef, shapes = model.flatten(params)
        n = xs.shape[0]
        for i in range(n):
            def f(p):
                return model.log_prob(model.unflatten(p, treedef, shapes), xs[i], ys[i])
            gi = jax.grad(f)(flat)
            np.testing.assert_allclose(s[i] * jnp.sqrt(n), gi, rtol=1e-5, atol=1e-6)

    def test_gradient_is_linear_image_of_scores(self):
        # v = −(1/√n)·Σᵢ Sᵢ — the structure RVB exploits and Algorithm 1
        # doesn't need (§3).
        params, xs, ys = setup(seed=1)
        s = model.score_matrix(params, xs, ys)
        n = xs.shape[0]
        v_from_s = -jnp.sum(s, axis=0) / jnp.sqrt(n)
        flat, treedef, shapes = model.flatten(params)
        def loss(p):
            return model.batch_loss(model.unflatten(p, treedef, shapes), xs, ys)
        v_autodiff = jax.grad(loss)(flat)
        np.testing.assert_allclose(v_from_s, v_autodiff, rtol=1e-5, atol=1e-6)

    def test_flatten_unflatten_roundtrip(self):
        params, _, _ = setup(seed=2)
        flat, treedef, shapes = model.flatten(params)
        back = model.unflatten(flat, treedef, shapes)
        for (w1, b1), (w2, b2) in zip(params, back):
            np.testing.assert_array_equal(w1, w2)
            np.testing.assert_array_equal(b1, b2)


class TestNgdStep:
    def test_descends(self):
        params, xs, ys = setup(n=24, seed=3)
        flat, treedef, shapes = model.flatten(params)
        l0 = float(model.batch_loss(params, xs, ys))
        # λ well above the f32 noise floor: with n ≪ m the tiny-σ
        # directions are amplified by (σ²+λ)⁻¹, so under-damping diverges
        # — exactly the §1 "damping becomes essential" point.
        for _ in range(8):
            flat, loss = model.ngd_step(flat, treedef, shapes, xs, ys, 0.1, 0.5)
        l1 = float(model.batch_loss(model.unflatten(flat, treedef, shapes), xs, ys))
        assert l1 < 0.7 * l0, f"{l0} → {l1}"

    def test_jits_cleanly(self):
        params, xs, ys = setup(n=8, seed=4)
        flat, treedef, shapes = model.flatten(params)
        step = jax.jit(
            lambda p, x, y: model.ngd_step(p, treedef, shapes, x, y, 1e-2, 0.3)
        )
        new_flat, loss = step(flat, xs, ys)
        assert new_flat.shape == flat.shape
        assert jnp.isfinite(loss)
