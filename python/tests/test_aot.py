"""AOT pipeline: lowered HLO text re-loads and re-executes in-process
(the Python half of the artifact round-trip; the Rust half lives in
rust/tests/runtime_artifacts.rs)."""

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from compile import aot
from compile.kernels import ref


def test_parse_shapes():
    assert aot.parse_shapes("8x32,16X512") == [(8, 32), (16, 512)]
    assert aot.parse_shapes("") == []


def test_lowered_solve_is_valid_hlo_and_executes():
    n, m = 8, 32
    text = aot.lower_solve(n, m)
    assert "HloModule" in text
    # Round-trip: parse the text back and execute on the local CPU client.
    comp = xc._xla.hlo_module_from_text(text)
    # Re-executing through jax is simpler: rebuild the computation and
    # compare against the oracle at concrete inputs.
    rng = np.random.default_rng(0)
    s = jnp.asarray(rng.normal(size=(n, m)), dtype=jnp.float32)
    v = jnp.asarray(rng.normal(size=(m,)), dtype=jnp.float32)
    lam = jnp.float32(0.1)
    from compile import solvers

    got = solvers.damped_solve(s, v, lam)
    want = ref.damped_solve_dense_oracle(s, v, lam)
    scale = 1.0 + float(jnp.max(jnp.abs(want)))
    np.testing.assert_allclose(got, want, rtol=0, atol=3e-3 * scale)


def test_lowered_gram_is_valid_hlo():
    text = aot.lower_gram(16, 64)
    assert "HloModule" in text
    assert len(text) > 1000


def test_artifact_contract_names():
    # The Rust registry parses solve_n{n}_m{m}.hlo.txt — keep the
    # contract pinned here so a rename breaks loudly on both sides.
    import re

    name = f"solve_n{8}_m{32}.hlo.txt"
    m = re.fullmatch(r"solve_n(\d+)_m(\d+)\.hlo\.txt", name)
    assert m and m.groups() == ("8", "32")
