"""Numerical oracle for the PR-10 structured-Fisher solver family (no
Rust toolchain needed): mirrors, algorithm-for-algorithm,

* the block-diagonal session (`rust/src/solver/blockdiag.rs`) — per-block
  damped solves on column shards, single-block ≡ exact;
* the KP-SVD kind (`rust/src/solver/kpsvd.rs`) — Van Loan–Pitsianis
  rearrangement, 40-step deterministic power iteration from vec(I_q),
  symmetrize + joint sign fix, damped Kronecker eigen-solve, and the
  p == 1 prime fallback (exact block eigh);
* the hybrid PCG (`rust/src/solver/hybrid.rs`) and plain CG
  (`rust/src/solver/cg.rs`) loops, including the PR-5 true-residual
  verification / residual-replacement restart, so the reported
  iteration counts have the same semantics as `CgStats.iterations`.

Run:  python3 python/oracle_structured.py

The scenarios mirror `rust/tests/structured.rs`, the in-module unit
tests (seeds 1001–1304) and `bench_tables::structured_bench` shapes
(quick and full). The RNG is numpy's, not the crate's xorshift, so the
oracle answers the *statistical* questions — is the KP-SVD exact on
Kronecker Grams, does PCG strictly beat CG on every pinned scenario
with margin, does everything converge under the 10 000-iteration cap —
not the bitwise one (bit-identity is chol-vs-chol on identical inputs,
which numpy cannot refute or confirm).
"""

import numpy as np

POWER_ITERS = 40  # kpsvd.rs::POWER_ITERS


# ---------------------------------------------------------------- exact


def chol_solve(s, v, lam):
    """Algorithm-1 (Woodbury) damped solve, the chol reference."""
    n = s.shape[0]
    a = s @ s.T + lam * np.eye(n)
    z = np.linalg.solve(a, s @ v)
    return (v - s.T @ z) / lam


def uniform_ranges(m, k):
    """BlockPartition::uniform — first m % k blocks get the extra col."""
    assert 0 < k <= m
    base, rem = divmod(m, k)
    ranges, start = [], 0
    for i in range(k):
        ln = base + (1 if i < rem else 0)
        ranges.append((start, start + ln))
        start += ln
    return ranges


def blockdiag_solve(s, v, lam, ranges):
    x = np.zeros_like(v)
    for c0, c1 in ranges:
        x[c0:c1] = chol_solve(s[:, c0:c1], v[c0:c1], lam)
    return x


# ---------------------------------------------------------------- kpsvd


def split_dim(mb):
    best, d = 1, 1
    while d * d <= mb:
        if mb % d == 0:
            best = d
        d += 1
    return best


def rearrange(g, p, q):
    r = np.zeros((p * p, q * q))
    for i in range(p):
        for j in range(p):
            r[i * p + j] = g[i * q : (i + 1) * q, j * q : (j + 1) * q].reshape(-1)
    return r


def kron_block(g):
    """Mirror of KpSvdFactor::kron_block → (alpha, beta, ua, ub, p, q)."""
    mb = g.shape[0]
    p = split_dim(mb)
    q = mb // p
    if p == 1:
        beta, ub = np.linalg.eigh(g)
        return np.array([1.0]), np.maximum(beta, 0.0), np.eye(1), ub, p, q
    r = rearrange(g, p, q)
    v = np.eye(q).reshape(-1)
    v /= np.linalg.norm(v)
    for _ in range(POWER_ITERS):
        w = r.T @ (r @ v)
        wn = np.linalg.norm(w)
        if wn <= 0.0:
            break
        v = w / wn
    u = r @ v  # σ₁·u₁ — singular value absorbed into A
    a = u.reshape(p, p)
    b = v.reshape(q, q)
    a = 0.5 * (a + a.T)
    b = 0.5 * (b + b.T)
    if np.trace(b) < 0.0:
        a, b = -a, -b
    alpha, ua = np.linalg.eigh(a)
    beta, ub = np.linalg.eigh(b)
    return np.maximum(alpha, 0.0), np.maximum(beta, 0.0), ua, ub, p, q


def kpsvd_solve(s, v, lam, ranges):
    x = np.zeros_like(v)
    for c0, c1 in ranges:
        sb = s[:, c0:c1]
        alpha, beta, ua, ub, p, q = kron_block(sb.T @ sb)
        vmat = v[c0:c1].reshape(p, q)
        w = ua.T @ vmat @ ub
        w = w / (alpha[:, None] * beta[None, :] + lam)
        x[c0:c1] = (ua @ w @ ub.T).reshape(-1)
    return x


# ----------------------------------------------------------- cg and pcg


def cg_iters(s, v, lam, tol=1e-10, max_iters=10_000):
    """Plain CG, mirroring CgFactor::solve_into (incl. true-residual
    verify + residual-replacement restart). Returns (x, iters, status).
    """
    m = s.shape[1]
    vnorm = max(np.linalg.norm(v), np.finfo(float).tiny)
    fisher = lambda p: s.T @ (s @ p) + lam * p
    x = np.zeros(m)
    r = v.copy()
    p = v.copy()
    rr = r @ r
    for it in range(max_iters):
        if np.sqrt(rr) <= tol * vnorm:
            r_true = v - fisher(x)
            if np.linalg.norm(r_true) <= tol * vnorm:
                return x, it, "converged"
            r = r_true
            rr = r @ r
            p = r.copy()
        ap = fisher(p)
        al = rr / (p @ ap)
        x += al * p
        r -= al * ap
        rr_new = r @ r
        beta = rr_new / rr
        rr = rr_new
        p = r + beta * p
    final = np.linalg.norm(v - fisher(x)) / vnorm
    return x, max_iters, "converged-at-cap" if final <= tol else "DID-NOT-CONVERGE"


def pcg_iters(s, v, lam, ranges, tol=1e-10, max_iters=10_000):
    """Hybrid PCG, mirroring HybridCgFactor::solve_into: block-diagonal
    preconditioner damped at the same λ, convergence judged on the exact
    system's residual norm, true-residual verify + restart.
    """
    m = s.shape[1]
    vnorm = max(np.linalg.norm(v), np.finfo(float).tiny)
    fisher = lambda p: s.T @ (s @ p) + lam * p
    pre = lambda r: blockdiag_solve(s, r, lam, ranges)
    x = np.zeros(m)
    r = v.copy()
    z = pre(r)
    p = z.copy()
    rz = r @ z
    for it in range(max_iters):
        if np.linalg.norm(r) <= tol * vnorm:
            r_true = v - fisher(x)
            if np.linalg.norm(r_true) <= tol * vnorm:
                return x, it, "converged"
            r = r_true
            z = pre(r)
            p = z.copy()
            rz = r @ z
        ap = fisher(p)
        al = rz / (p @ ap)
        x += al * p
        r -= al * ap
        z = pre(r)
        rz_new = r @ z
        beta = rz_new / rz
        rz = rz_new
        p = z + beta * p
    final = np.linalg.norm(v - fisher(x)) / vnorm
    return x, max_iters, "converged-at-cap" if final <= tol else "DID-NOT-CONVERGE"


# ------------------------------------------------------------ scenarios


def blocked_scores(n_per, blocks, width, rng, spread_cap=None, coupling=0.0):
    """hybrid.rs helper (scale 10^b) or, with spread_cap, the
    tests/structured.rs + bench variant (scale 10^(cap·b/(k−1)), faint
    dense coupling)."""
    n, m = n_per * blocks, width * blocks
    s = np.zeros((n, m))
    denom = max(blocks, 2) - 1
    for b in range(blocks):
        scale = 10.0 ** (spread_cap * b / denom) if spread_cap else 10.0**b
        s[b * n_per : (b + 1) * n_per, b * width : (b + 1) * width] = (
            scale * rng.standard_normal((n_per, width))
        )
    if coupling:
        s += coupling * rng.standard_normal((n, m))
    return s


def kron_scores(a, b):
    """Column convention (i, k) → i·q + k, matching the session."""
    na, p = a.shape
    nb, q = b.shape
    out = np.zeros((na * nb, p * q))
    for i in range(p):
        for k in range(q):
            out[:, i * q + k] = np.outer(a[:, i], b[:, k]).reshape(-1)
    return out


def check(label, ok, detail):
    print(f"  [{'ok' if ok else 'FAIL'}] {label}: {detail}")
    return ok


def main():
    all_ok = True
    rngs = lambda seed: np.random.default_rng(seed)

    print("== block-diagonal sessions (blockdiag.rs / structured.rs) ==")
    for seed in range(8):
        rng = rngs(seed)
        s = rng.standard_normal((8, 24))
        v = rng.standard_normal(24)
        x1 = blockdiag_solve(s, v, 0.3, uniform_ranges(24, 1))
        xc = chol_solve(s, v, 0.3)
        gap1 = np.max(np.abs(x1 - xc))
        xk = blockdiag_solve(s, v, 0.3, uniform_ranges(24, 3))
        per = np.concatenate(
            [chol_solve(s[:, c0:c1], v[c0:c1], 0.3) for c0, c1 in uniform_ranges(24, 3)]
        )
        gapk = np.max(np.abs(xk - per))
        all_ok &= check(
            f"seed {seed}: 1-block ≡ exact, k-block ≡ independent",
            gap1 < 1e-12 and gapk == 0.0,
            f"gap1={gap1:.1e} gapk={gapk:.1e}",
        )

    print("== KP-SVD (kpsvd.rs) ==")
    # Exact on Kronecker-structured scores: S = A⊗B (seeds 1101, 1303).
    for seed in range(8):
        rng = rngs(seed)
        s = kron_scores(rng.standard_normal((3, 4)), rng.standard_normal((4, 5)))
        v = rng.standard_normal(s.shape[1])
        worst = 0.0
        for lam in (1.0, 0.1, 0.01):
            x = kpsvd_solve(s, v, lam, [(0, s.shape[1])])
            xc = chol_solve(s, v, lam)
            worst = max(worst, np.max(np.abs(x - xc)))
        all_ok &= check(
            f"seed {seed}: exact on S = A⊗B (m=20, λ∈{{1,.1,.01}})",
            worst < 1e-8,
            f"max|Δx|={worst:.1e}",
        )
    # Prime block width → p == 1 exact-eigh fallback (seed 1102).
    for seed in range(4):
        rng = rngs(100 + seed)
        s = rng.standard_normal((6, 13))
        v = rng.standard_normal(13)
        x = kpsvd_solve(s, v, 0.05, [(0, 13)])
        xc = chol_solve(s, v, 0.05)
        gap = np.max(np.abs(x - xc))
        all_ok &= check(f"seed {seed}: prime width m=13 exact", gap < 1e-9, f"max|Δx|={gap:.1e}")
    # Approximation gap on unstructured random S — the EXPERIMENTS.md
    # regime table (relative solution error vs exact, per block count).
    rng = rngs(7)
    s = rng.standard_normal((48, 768))
    v = rng.standard_normal(768)
    lam = 1e-3
    xc = chol_solve(s, v, lam)
    xn = np.linalg.norm(xc)
    print("  kpsvd relative solution error on dense random S (n=48, m=768, λ=1e-3):")
    for k in (1, 4, 16, 64):
        x = kpsvd_solve(s, v, lam, uniform_ranges(768, k))
        print(f"    blocks={k:3d}: ‖x−x*‖/‖x*‖ = {np.linalg.norm(x - xc) / xn:.3f}")

    print("== hybrid PCG vs plain CG (hybrid.rs / cg.rs semantics) ==")
    # All iteration comparisons run at the shared tol 1e-7 the Rust tests
    # and bench pin: f64's attainable true residual is ~ε·κ(SᵀS+λI)·‖v‖,
    # so with the ~10³ Gram spread (κ ≈ 1e7 at λ=1e-3) a 1e-10 target is
    # unreachable — both solvers would stall at the cap (this oracle is
    # what caught that; the scenarios were retuned accordingly).
    tol = 1e-7
    scenarios = [
        # (label, S builder, blocks, lambda)
        ("hybrid.rs unit: 16×24, 4 blocks, 10^(b/2) spread",
         lambda rng: blocked_scores(4, 4, 6, rng, spread_cap=1.5), 4, 1e-3),
        ("structured.rs: 16×32, 4 blocks, 10^1.5 spread",
         lambda rng: blocked_scores(4, 4, 8, rng, spread_cap=1.5), 4, 1e-3),
    ]
    for k in (4, 16, 64):
        for tag, m in (("bench quick", 768), ("bench full", 2048)):
            width = max(m // k, 2)
            scenarios.append((
                f"{tag}: blocks={k} (6 rows/block, 10^1.5 spread, 1e-3 coupling)",
                lambda rng, k=k, width=width: blocked_scores(
                    6, k, width, rng, spread_cap=1.5, coupling=1e-3
                ),
                k,
                1e-3,
            ))
    for label, make, k, lam in scenarios:
        worst_margin, statuses = np.inf, set()
        for seed in range(4):
            rng = rngs(1000 + seed)
            s = make(rng)
            v = rng.standard_normal(s.shape[1])
            ranges = uniform_ranges(s.shape[1], k)
            x_cg, it_cg, st_cg = cg_iters(s, v, lam, tol=tol)
            x_pcg, it_pcg, st_pcg = pcg_iters(s, v, lam, ranges, tol=tol)
            statuses |= {st_cg, st_pcg}
            worst_margin = min(worst_margin, it_cg - it_pcg)
            xc = chol_solve(s, v, lam)
            scale = max(np.max(np.abs(xc)), 1.0)
            assert np.max(np.abs(x_pcg - xc)) < 1e-5 * scale, "pcg answer drifted"
        all_ok &= check(
            label,
            worst_margin > 0 and "DID-NOT-CONVERGE" not in statuses,
            f"min(cg−pcg)={worst_margin} statuses={sorted(statuses)}",
        )

    # Dense random S at the bench timing grid's λ = 0.1 and the hybrid's
    # default 1e-10 inner tolerance: must converge under the cap even
    # though the preconditioner is crude. (At λ = 1e-3 the 1e-10 target
    # sits below the attainable floor on the full shape — that is why
    # the timing grid runs at λ = 0.1.)
    for n, m in ((48, 768), (96, 2048)):
        rng = rngs(42)
        s = rng.standard_normal((n, m))
        v = rng.standard_normal(m)
        _, it, st = pcg_iters(s, v, 0.1, uniform_ranges(m, 64), tol=1e-10)
        all_ok &= check(
            f"dense random n={n} m={m}, λ=0.1, 64-block preconditioner, tol 1e-10",
            st == "converged",
            f"pcg iters={it} status={st}",
        )

    # The optimizer test's registry-default hybrid: randn (8, 24) at
    # λ = 1e-4, tol 1e-10, blocks unset (→ one exact chol block). The
    # small ‖S‖ keeps the attainable floor under 1e-10 here.
    for seed in range(4):
        rng = rngs(500 + seed)
        s = rng.standard_normal((8, 24))
        v = rng.standard_normal(24)
        _, it_c, st_c = cg_iters(s, v, 1e-4, tol=1e-10)
        _, it_p, st_p = pcg_iters(s, v, 1e-4, [(0, 24)], tol=1e-10)
        all_ok &= check(
            f"seed {seed}: optimizer shape 8×24, λ=1e-4, registry-default tol 1e-10",
            st_c == "converged" and st_p == "converged",
            f"cg={it_c} ({st_c}) pcg={it_p} ({st_p})",
        )

    print("ALL SCENARIOS PASS" if all_ok else "SOME SCENARIOS FAILED")
    return 0 if all_ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
