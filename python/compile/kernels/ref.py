"""Pure-jnp oracles for every Pallas kernel and for the full solve.

These are the CORE correctness signal: each L1 kernel in this package is
asserted allclose against its oracle here (pytest + hypothesis sweeps),
and the fused solve is additionally checked against ``jnp.linalg.solve``
on the materialized m-by-m system.
"""

import jax.numpy as jnp
import jax.scipy.linalg as jsl


def gram_ref(s, lam):
    """W = S·Sᵀ + λĨ — Algorithm 1 line 1."""
    n = s.shape[0]
    return s @ s.T + lam * jnp.eye(n, dtype=s.dtype)


def matvec_ref(s, v):
    """u = S·v."""
    return s @ v


def tmatvec_ref(s, z):
    """t = Sᵀ·z (the kernel never materializes Sᵀ; the oracle may)."""
    return s.T @ z


def cholesky_ref(w):
    """Lower-triangular L with L·Lᵀ = W."""
    return jnp.linalg.cholesky(w)


def trisolve_ref(l, b, trans=False):
    """Solve L y = b (or Lᵀ y = b with trans=True), L lower-triangular."""
    return jsl.solve_triangular(l, b, lower=True, trans=1 if trans else 0)


def damped_solve_ref(s, v, lam):
    """Algorithm 1 end-to-end, pure jnp (the L2 reference path)."""
    w = gram_ref(s, lam)
    l = cholesky_ref(w)
    u = s @ v
    y = trisolve_ref(l, u, trans=False)
    z = trisolve_ref(l, y, trans=True)
    return (v - s.T @ z) / lam


def damped_solve_dense_oracle(s, v, lam):
    """Independent oracle: materialize the m×m system and solve it.

    O(m³) — tests only. Validates Algorithm 1 itself, not just the
    kernel plumbing.
    """
    m = s.shape[1]
    fisher = s.T @ s + lam * jnp.eye(m, dtype=s.dtype)
    return jnp.linalg.solve(fisher, v)


def eigh_solve_ref(s, v, lam):
    """Appendix C, Eq. 5 via the Gram eigendecomposition ("eigh")."""
    w = s @ s.T
    evals, u = jnp.linalg.eigh(w)
    evals = jnp.clip(evals, 0.0, None)
    sigma = jnp.sqrt(evals)
    # V = Sᵀ U Σ⁻¹, guarding σ≈0 columns (they are handled by the λ term).
    safe = jnp.where(sigma > 1e-12 * jnp.max(sigma), sigma, jnp.inf)
    vt = (u.T @ s) / safe[:, None]  # rows are right singular vectors
    wv = vt @ v
    x_range = vt.T @ (wv / (evals + lam))
    proj = vt.T @ wv
    return x_range + (v - proj) / lam


def svd_solve_ref(s, v, lam):
    """Appendix C, Eq. 5 via a direct SVD (the "svda" stand-in at L2)."""
    u, sigma, vt = jnp.linalg.svd(s, full_matrices=False)
    wv = vt @ v
    x_range = vt.T @ (wv / (sigma**2 + lam))
    proj = vt.T @ wv
    return x_range + (v - proj) / lam
