"""L1 Pallas kernels: the two O(nm) streaming passes of Algorithm 1 line 4.

* ``matvec``  — `u = S·v`   (right-to-left evaluation, first pass)
* ``tmatvec`` — `t = Sᵀ·z`  (last pass; never materializes Sᵀ — the
  kernel reads S tiles in their native layout and contracts on the other
  axis, which is the TPU analogue of the paper's "Q can be inlined"
  note: no transposed copy is ever written)

Both are memory-bound: one HBM read of S per call. Tiles are
`block_n × block_m` with the reduction axis innermost so the output
block accumulates in VMEM.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matvec_kernel(s_ref, v_ref, o_ref):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        s_ref[...], v_ref[...], preferred_element_type=o_ref.dtype
    )


@functools.partial(jax.jit, static_argnames=("block_n", "block_m"))
def matvec(s, v, block_n=128, block_m=2048):
    """u = S·v (n×m @ m)."""
    n, m = s.shape
    bn = min(block_n, max(n, 1))
    bm = min(block_m, max(m, 1))
    n_pad = -(-n // bn) * bn
    m_pad = -(-m // bm) * bm
    sp = jnp.pad(s, ((0, n_pad - n), (0, m_pad - m)))
    vp = jnp.pad(v, (0, m_pad - m))
    grid = (n_pad // bn, m_pad // bm)
    out = pl.pallas_call(
        _matvec_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bm), lambda i, k: (i, k)),
            pl.BlockSpec((bm,), lambda i, k: (k,)),
        ],
        out_specs=pl.BlockSpec((bn,), lambda i, k: (i,)),
        out_shape=jax.ShapeDtypeStruct((n_pad,), s.dtype),
        interpret=True,
    )(sp, vp)
    return out[:n]


def _tmatvec_kernel(s_ref, z_ref, o_ref):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # Contract along the row axis of the native-layout S tile: Sᵀz
    # without a transposed copy.
    o_ref[...] += jnp.dot(
        z_ref[...], s_ref[...], preferred_element_type=o_ref.dtype
    )


@functools.partial(jax.jit, static_argnames=("block_n", "block_m"))
def tmatvec(s, z, block_n=128, block_m=2048):
    """t = Sᵀ·z (m×n @ n), streaming S in native row-major tiles."""
    n, m = s.shape
    bn = min(block_n, max(n, 1))
    bm = min(block_m, max(m, 1))
    n_pad = -(-n // bn) * bn
    m_pad = -(-m // bm) * bm
    sp = jnp.pad(s, ((0, n_pad - n), (0, m_pad - m)))
    zp = jnp.pad(z, (0, n_pad - n))
    grid = (m_pad // bm, n_pad // bn)
    out = pl.pallas_call(
        _tmatvec_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bm), lambda j, k: (k, j)),
            pl.BlockSpec((bn,), lambda j, k: (k,)),
        ],
        out_specs=pl.BlockSpec((bm,), lambda j, k: (j,)),
        out_shape=jax.ShapeDtypeStruct((m_pad,), s.dtype),
        interpret=True,
    )(sp, zp)
    return out[:m]
