"""L1 Pallas kernels: forward / adjoint triangular solves (Algorithm 1
line 4's `L⁻¹(·)` and `L⁻ᵀ(·)`).

Like the Cholesky kernel these are VMEM-resident latency kernels over the
n×n factor; the O(nm) work of line 4 lives in the matvec kernels. The
substitution loop is expressed with masked rank-1 updates so the whole
solve is one `fori_loop` over rows — no dynamic slicing beyond indexed
gathers, which keeps the Mosaic lowering trivial.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fwd_kernel(l_ref, b_ref, y_ref):
    l = l_ref[...]
    b = b_ref[...]
    n = l.shape[0]
    idx = jnp.arange(n)

    def body(i, y):
        # y_i = (b_i − Σ_{j<i} L[i,j]·y_j) / L[i,i]
        mask = (idx < i).astype(l.dtype)
        yi = (b[i] - jnp.dot(l[i, :] * mask, y)) / l[i, i]
        return y.at[i].set(yi)

    y_ref[...] = jax.lax.fori_loop(0, n, body, jnp.zeros_like(b))


def _adj_kernel(l_ref, y_ref, z_ref):
    l = l_ref[...]
    y = y_ref[...]
    n = l.shape[0]
    idx = jnp.arange(n)

    def body(t, z):
        i = n - 1 - t
        # z_i = (y_i − Σ_{j>i} Lᵀ[i,j]·z_j) / L[i,i];  Lᵀ[i,j] = L[j,i]
        mask = (idx > i).astype(l.dtype)
        zi = (y[i] - jnp.dot(l[:, i] * mask, z)) / l[i, i]
        return z.at[i].set(zi)

    z_ref[...] = jax.lax.fori_loop(0, n, body, jnp.zeros_like(y))


def solve_lower(l, b):
    """y with L·y = b (forward substitution)."""
    n = l.shape[0]
    return pl.pallas_call(
        _fwd_kernel,
        out_shape=jax.ShapeDtypeStruct((n,), b.dtype),
        interpret=True,
    )(l, b)


def solve_lower_t(l, y):
    """z with Lᵀ·z = y (backward substitution on the transpose)."""
    n = l.shape[0]
    return pl.pallas_call(
        _adj_kernel,
        out_shape=jax.ShapeDtypeStruct((n,), y.dtype),
        interpret=True,
    )(l, y)
