"""L1 Pallas kernel: VMEM-resident Cholesky factorization (Algorithm 1
line 2).

The Gram matrix is only n×n (≤ 4096² f32 = 64 MB at the paper's largest
shape; ≤ 1 MB at the artifact shapes this repo ships), so unlike the
O(n²m) Gram stage it is a *latency* kernel, not a bandwidth kernel. The
whole factorization runs on one VMEM-resident block with a `fori_loop`
over columns — the TPU analogue of cuSOLVER's single-block `potrf` panel
factorization. Larger-than-VMEM n would use the blocked right-looking
recursion (panel = this kernel, trailing update = the Gram kernel);
DESIGN.md §Perf carries the estimate.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _chol_kernel(w_ref, l_ref):
    w = w_ref[...]
    n = w.shape[0]
    idx = jnp.arange(n)

    def body(j, l):
        # Masked column-j update (Cholesky–Crout with traced j):
        #   lj  = row j of L restricted to k < j
        #   d   = sqrt(w[j,j] − ‖lj‖²)
        #   col = (w[:,j] − L·lj)/d, zeroed above the diagonal.
        mask = (idx < j).astype(w.dtype)
        lj = l[j, :] * mask
        d = jnp.sqrt(w[j, j] - jnp.dot(lj, lj))
        s = l @ lj
        col = (w[:, j] - s) / d
        col = jnp.where(idx == j, d, col)
        col = jnp.where(idx < j, jnp.zeros_like(col), col)
        return l.at[:, j].set(col)

    l_ref[...] = jax.lax.fori_loop(0, n, body, jnp.zeros_like(w))


def cholesky(w):
    """Lower Cholesky factor of an SPD matrix, single-block Pallas."""
    n = w.shape[0]
    assert w.shape == (n, n)
    return pl.pallas_call(
        _chol_kernel,
        out_shape=jax.ShapeDtypeStruct((n, n), w.dtype),
        interpret=True,
    )(w)
