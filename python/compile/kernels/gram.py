"""L1 Pallas kernel: tiled Gram matrix `W = S·Sᵀ + λĨ` (Algorithm 1, line 1).

This is the only O(n²m) stage of the paper's algorithm — the kernel that
has to be right on real hardware. The GPU formulation in the paper is a
cuBLAS SYRK over HBM; the TPU re-think (DESIGN.md §Hardware-Adaptation):

* grid `(n/bn, n/bn, m/bk)` — output tiles × reduction slabs;
* each step pulls one `bn×bk` tile of S per operand HBM→VMEM via
  BlockSpec and feeds the MXU with a `bn×bk @ bk×bn` contraction
  (bn=128 matches the 128×128 systolic array; bk=512 keeps the two
  input tiles + f32 accumulator ≈ 128·512·4·2 + 128·128·4 ≈ 0.6 MB,
  comfortably double-bufferable in ~16 MB VMEM);
* the reduction dimension is the innermost grid axis, so the output
  tile stays resident in VMEM across the whole m-sweep (revolving
  accumulator), exactly the role of the K-loop in a threadblock SYRK.

``interpret=True`` everywhere: real-TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot execute; numerics are validated
through the interpret path and perf is estimated from the tiling
(DESIGN.md §Perf).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gram_kernel(si_ref, sj_ref, o_ref):
    """One (i, j, k) grid step: o[i,j] += S[i,k] @ S[j,k]ᵀ."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        si_ref[...], sj_ref[...].T, preferred_element_type=o_ref.dtype
    )


def _pad_to(x, rows, cols):
    pr = rows - x.shape[0]
    pc = cols - x.shape[1]
    if pr == 0 and pc == 0:
        return x
    return jnp.pad(x, ((0, pr), (0, pc)))


@functools.partial(jax.jit, static_argnames=("block_n", "block_k"))
def gram(s, lam, block_n=128, block_k=512):
    """W = S·Sᵀ + λĨ via the tiled Pallas kernel.

    Shapes are padded up to tile multiples with zeros — exact for a Gram
    product (zero columns contribute nothing; zero rows only pad W with
    zeros, sliced off afterwards).
    """
    n, m = s.shape
    bn = min(block_n, max(n, 1))
    bk = min(block_k, max(m, 1))
    n_pad = -(-n // bn) * bn
    m_pad = -(-m // bk) * bk
    sp = _pad_to(s, n_pad, m_pad)

    grid = (n_pad // bn, n_pad // bn, m_pad // bk)
    out = pl.pallas_call(
        _gram_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bn, bk), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((bn, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n_pad, n_pad), s.dtype),
        interpret=True,
    )(sp, sp)
    w = out[:n, :n]
    return w + lam * jnp.eye(n, dtype=s.dtype)


def vmem_bytes(block_n=128, block_k=512, dtype_bytes=4):
    """Modeled VMEM working set of one grid step (perf estimate input)."""
    tiles_in = 2 * block_n * block_k * dtype_bytes  # two S tiles
    acc = block_n * block_n * 4  # f32 accumulator
    return 2 * tiles_in + acc  # ×2: double buffering of the input tiles
