"""L2: the damped Fisher solvers as JAX computation graphs.

``damped_solve`` is the paper's Algorithm 1 composed from the L1 Pallas
kernels (Gram → Cholesky → two triangular solves → two streaming
matvecs); it is the function ``aot.py`` lowers to the PJRT artifacts the
Rust runtime executes. ``eigh_solve``/``svd_solve``/``cg_solve`` are the
baselines at L2, used by ``bench_jax.py`` to regenerate the paper's
Table 1 comparison on this testbed's JAX path.
"""

import jax
import jax.numpy as jnp

from .kernels import cholesky as chol_kernel
from .kernels import gram as gram_kernel
from .kernels import matvec as mv_kernel
from .kernels import ref
from .kernels import trisolve as tri_kernel


def damped_solve(s, v, lam):
    """Algorithm 1: x with (SᵀS + λI)x = v, via the Pallas kernels.

    Right-to-left evaluation of x = (v − SᵀL⁻ᵀL⁻¹Sv)/λ, per the paper's
    implementation note (Q is never materialized).
    """
    w = gram_kernel.gram(s, lam)
    l = chol_kernel.cholesky(w)
    u = mv_kernel.matvec(s, v)
    y = tri_kernel.solve_lower(l, u)
    z = tri_kernel.solve_lower_t(l, y)
    t = mv_kernel.tmatvec(s, z)
    return (v - t) / lam


def damped_solve_jnp(s, v, lam):
    """Algorithm 1 in pure jnp (XLA-fused reference path, no Pallas)."""
    return ref.damped_solve_ref(s, v, lam)


def eigh_solve(s, v, lam):
    """The paper's "eigh" baseline (Appendix C)."""
    return ref.eigh_solve_ref(s, v, lam)


def svd_solve(s, v, lam):
    """The paper's "svda" baseline at L2 (LAPACK SVD stand-in)."""
    return ref.svd_solve_ref(s, v, lam)


def cg_solve(s, v, lam, tol=1e-10, max_iters=10_000):
    """Conjugate-gradient baseline (§3), matrix-free."""

    def fisher_apply(p):
        return s.T @ (s @ p) + lam * p

    def cond(state):
        _, r, _, rr, it = state
        return jnp.logical_and(rr > (tol * jnp.linalg.norm(v)) ** 2, it < max_iters)

    def body(state):
        x, r, p, rr, it = state
        ap = fisher_apply(p)
        alpha = rr / jnp.dot(p, ap)
        x = x + alpha * p
        r = r - alpha * ap
        rr_new = jnp.dot(r, r)
        p = r + (rr_new / rr) * p
        return (x, r, p, rr_new, it + 1)

    x0 = jnp.zeros_like(v)
    state = (x0, v, v, jnp.dot(v, v), jnp.array(0))
    x, _, _, _, iters = jax.lax.while_loop(cond, body, state)
    return x, iters
