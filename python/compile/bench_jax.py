"""L2 bench harness: regenerates the paper's Table 1 / Fig. 1 comparison
on the JAX path (chol vs eigh vs svd), CPU edition.

The paper's absolute numbers are A100 milliseconds; the reproduction
target is the *shape* of the comparison — chol fastest, eigh next, svd
slowest, O(n²) scaling in n and O(m) in m (see EXPERIMENTS.md). Shapes
are scaled down from the paper's (CPU testbed); pass --paper-scale to run
the original sizes if you have the patience.

Usage::

    python -m compile.bench_jax [--repeats 5] [--paper-scale]
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import solvers

# Scaled-down Table 1 grid (same aspect progression as the paper).
N_SWEEP = [(64, 8192), (128, 8192), (256, 8192), (512, 8192)]
M_SWEEP = [(256, 2048), (256, 4096), (256, 8192), (256, 16384)]
PAPER_N_SWEEP = [(256, 100_000), (512, 100_000), (1024, 100_000), (2048, 100_000), (4096, 100_000)]
PAPER_M_SWEEP = [(2048, 10_000), (2048, 20_000), (2048, 50_000), (2048, 100_000), (2048, 200_000)]

METHODS = {
    "chol": solvers.damped_solve_jnp,
    "eigh": solvers.eigh_solve,
    "svda": solvers.svd_solve,
}


def time_method(fn, s, v, lam, repeats):
    jitted = jax.jit(fn)
    jitted(s, v, lam)[0].block_until_ready() if isinstance(
        jitted(s, v, lam), tuple
    ) else jitted(s, v, lam).block_until_ready()  # warm-up + compile
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = jitted(s, v, lam)
        (out[0] if isinstance(out, tuple) else out).block_until_ready()
        times.append(time.perf_counter() - t0)
    return 1e3 * float(np.median(times))


def run_sweep(shapes, lam, repeats, label):
    print(f"\n== {label} ==")
    print(f"{'shape':>18} | " + " | ".join(f"{m:>10}" for m in METHODS) + " | fastest")
    rows = []
    for n, m in shapes:
        rng = np.random.default_rng(n * 7919 + m)
        s = jnp.asarray(rng.normal(size=(n, m)), dtype=jnp.float32)
        v = jnp.asarray(rng.normal(size=(m,)), dtype=jnp.float32)
        ms = {name: time_method(fn, s, v, jnp.float32(lam), repeats) for name, fn in METHODS.items()}
        fastest = min(ms, key=ms.get)
        print(
            f"({n:>6},{m:>9}) | "
            + " | ".join(f"{ms[name]:>8.2f}ms" for name in METHODS)
            + f" | {fastest}"
        )
        rows.append((n, m, ms))
    return rows


def fit_exponent(xs, ys):
    lx, ly = np.log(xs), np.log(ys)
    a, _ = np.polyfit(lx, ly, 1)
    return a


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--repeats", type=int, default=5)
    ap.add_argument("--lam", type=float, default=1e-3)
    ap.add_argument("--paper-scale", action="store_true")
    args = ap.parse_args()

    n_sweep = PAPER_N_SWEEP if args.paper_scale else N_SWEEP
    m_sweep = PAPER_M_SWEEP if args.paper_scale else M_SWEEP

    rows_n = run_sweep(n_sweep, args.lam, args.repeats, "Fig. 1 left: time vs n (fixed m)")
    rows_m = run_sweep(m_sweep, args.lam, args.repeats, "Fig. 1 right: time vs m (fixed n)")

    # Fitted exponents vs the paper's dotted ideal lines (2 and 1).
    ns = [r[0] for r in rows_n]
    chol_n = [r[2]["chol"] for r in rows_n]
    ms_ = [r[1] for r in rows_m]
    chol_m = [r[2]["chol"] for r in rows_m]
    print(f"\nchol scaling: n-exponent {fit_exponent(ns, chol_n):.2f} (ideal 2), "
          f"m-exponent {fit_exponent(ms_, chol_m):.2f} (ideal 1)")


if __name__ == "__main__":
    main()
