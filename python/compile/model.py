"""L2: JAX model with per-sample score rows, and the fused NGD step.

The score matrix of §2, ``S_ij = (1/√n)·∂log P_θ(x_i)/∂θ_j``, is computed
with ``jax.vmap(jax.grad(...))`` — the autodiff path the paper's own JAX
implementation would use — and fed into Algorithm 1 from ``solvers.py``.
``ngd_step`` is the end-to-end graph: scores → gradient → damped solve →
updated parameters, lowered by ``aot.py`` when a model-step artifact is
requested.

The architecture here is an MLP classifier (matching the Rust-native
``model::mlp`` for cross-checks); the Rust transformer computes its own
scores natively and only offloads the *solve* to the PJRT artifact.
"""

import jax
import jax.numpy as jnp

from . import solvers


def init_mlp(sizes, key):
    """Xavier-init MLP parameters as a flat list of (W, b) pairs."""
    params = []
    keys = jax.random.split(key, len(sizes) - 1)
    for k, (fi, fo) in zip(keys, zip(sizes[:-1], sizes[1:])):
        scale = jnp.sqrt(2.0 / (fi + fo))
        params.append((scale * jax.random.normal(k, (fo, fi)), jnp.zeros(fo)))
    return params


def mlp_logits(params, x):
    """Forward pass: tanh hidden layers, linear head."""
    h = x
    for w, b in params[:-1]:
        h = jnp.tanh(w @ h + b)
    w, b = params[-1]
    return w @ h + b


def log_prob(params, x, y):
    """log p(y | x) under the softmax head."""
    logits = mlp_logits(params, x)
    return logits[y] - jax.scipy.special.logsumexp(logits)


def flatten(params):
    """Flatten a pytree of parameters into a single vector."""
    leaves, treedef = jax.tree_util.tree_flatten(params)
    flat = jnp.concatenate([l.ravel() for l in leaves])
    return flat, treedef, [l.shape for l in leaves]


def unflatten(flat, treedef, shapes):
    out = []
    pos = 0
    for shape in shapes:
        size = 1
        for d in shape:
            size *= d
        out.append(flat[pos : pos + size].reshape(shape))
        pos += size
    return jax.tree_util.tree_unflatten(treedef, out)


def score_matrix(params, xs, ys):
    """S (n×m): per-sample ∂log p/∂θ rows, scaled 1/√n (paper §2)."""
    flat, treedef, shapes = flatten(params)

    def per_sample(x, y):
        def f(p_flat):
            return log_prob(unflatten(p_flat, treedef, shapes), x, y)

        return jax.grad(f)(flat)

    rows = jax.vmap(per_sample)(xs, ys)
    n = xs.shape[0]
    return rows / jnp.sqrt(n)


def batch_loss(params, xs, ys):
    """Mean NLL over the batch."""
    lps = jax.vmap(lambda x, y: log_prob(params, x, y))(xs, ys)
    return -jnp.mean(lps)


def ngd_step(params_flat, treedef, shapes, xs, ys, lam, lr):
    """One fused NGD step on flat parameters: returns (new_flat, loss).

    v = ∇L = −(1/√n)·Σᵢ Sᵢ (log-likelihood structure), then Algorithm 1.
    """
    params = unflatten(params_flat, treedef, shapes)
    s = score_matrix(params, xs, ys)
    n = xs.shape[0]
    v = -jnp.sum(s, axis=0) / jnp.sqrt(n)
    loss = batch_loss(params, xs, ys)
    x = solvers.damped_solve_jnp(s, v, lam)
    return params_flat - lr * x, loss
