"""AOT pipeline: lower the L2 solve graphs to HLO **text** artifacts the
Rust PJRT runtime loads (`rust/src/runtime/pjrt.rs`).

HLO text — NOT ``lowered.compile()`` output or a serialized
``HloModuleProto`` — is the interchange format: jax ≥ 0.5 emits protos
with 64-bit instruction ids which the runtime's xla_extension 0.5.1
rejects (``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly. See /opt/xla-example/README.md.

Usage::

    python -m compile.aot --out-dir ../artifacts \
        --solve-shapes 8x32,16x512,64x4096 --gram-shapes 16x64

Artifact naming contract (parsed by ``runtime::artifacts``)::

    solve_n{n}_m{m}.hlo.txt   inputs (S: f32[n,m], v: f32[m], λ: f32[])
    gram_n{n}_m{m}.hlo.txt    inputs (S: f32[n,m], λ: f32[])

Outputs are 1-tuples (lowered with return_tuple=True; the Rust side
unwraps with ``to_tuple1``).
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import solvers


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (id-reassigning path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_solve(n: int, m: int) -> str:
    """Lower Algorithm 1 (Pallas-kernel composition) at a fixed shape."""

    def fn(s, v, lam):
        return (solvers.damped_solve(s, v, lam),)

    args = (
        jax.ShapeDtypeStruct((n, m), jnp.float32),
        jax.ShapeDtypeStruct((m,), jnp.float32),
        jax.ShapeDtypeStruct((), jnp.float32),
    )
    return to_hlo_text(jax.jit(fn).lower(*args))


def lower_gram(n: int, m: int) -> str:
    """Lower the Gram kernel alone (ablation / kernel-level artifact)."""
    from .kernels import gram as gram_kernel

    def fn(s, lam):
        return (gram_kernel.gram(s, lam),)

    args = (
        jax.ShapeDtypeStruct((n, m), jnp.float32),
        jax.ShapeDtypeStruct((), jnp.float32),
    )
    return to_hlo_text(jax.jit(fn).lower(*args))


def parse_shapes(spec: str):
    if not spec:
        return []
    out = []
    for part in spec.split(","):
        n, m = part.lower().split("x")
        out.append((int(n), int(m)))
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--solve-shapes",
        default="8x32,16x512,64x4096",
        help="comma-separated NxM shapes for solve artifacts",
    )
    ap.add_argument(
        "--gram-shapes",
        default="16x64",
        help="comma-separated NxM shapes for gram-only artifacts",
    )
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    for n, m in parse_shapes(args.solve_shapes):
        path = os.path.join(args.out_dir, f"solve_n{n}_m{m}.hlo.txt")
        text = lower_solve(n, m)
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")
    for n, m in parse_shapes(args.gram_shapes):
        path = os.path.join(args.out_dir, f"gram_n{n}_m{m}.hlo.txt")
        text = lower_gram(n, m)
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")


if __name__ == "__main__":
    main()
